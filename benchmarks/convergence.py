"""Paper Figs. 1/5/6: training-curve comparison across precision arms on a
small LLaMA, identical data and hyperparameters.

Arms (Fig. 6a): BF16 baseline, FP4 (W4A4+DGE+OCC), direct-cast W4A4.
Ablations: DGE-only (Fig. 6b, k sweep), OCC-only (Fig. 6c, alpha sweep),
granularity (Fig. 6d). CPU-scale: the model is tiny (the paper's claims are
about *relative* loss gaps between precision arms on identical data).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import PRESETS, QuantPolicy
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import adam as adam_mod
from repro.optim.schedule import warmup_cosine

CFG = get_config("llama2-400m", smoke=True).replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, loss_chunk=64)
SEQ, BATCH = 128, 8


def train_arm(policy: QuantPolicy, steps: int = 120, seed: int = 0,
              peak_lr: float = 1e-3):
    model = build_model(CFG, policy.replace(occ_threshold="exact")
                        if policy.occ else policy)
    params, _ = model.init(jax.random.PRNGKey(seed))
    adam_cfg = adam_mod.AdamConfig(weight_decay=0.01)
    opt = adam_mod.init_state(params, adam_cfg)
    data = SyntheticLM(DataConfig(CFG.vocab_size, SEQ, BATCH, seed=42))

    @jax.jit
    def step_fn(params, opt, batch, lr):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        grads, _ = adam_mod.clip_by_global_norm(grads, 1.0)
        params, opt = adam_mod.apply_update(params, grads, opt, lr, adam_cfg)
        return params, opt, loss

    losses = []
    for s in range(steps):
        batch = {"tokens": jnp.asarray(data.global_batch(s))}
        lr = warmup_cosine(s, total_steps=steps, peak_lr=peak_lr)
        params, opt, loss = step_fn(params, opt, batch, lr)
        losses.append(float(loss))
        if not np.isfinite(losses[-1]):
            break
    return losses


def _tail_mean(losses, k=10):
    good = [l for l in losses if np.isfinite(l)]
    if len(good) < len(losses):
        return float("nan")
    return float(np.mean(good[-k:]))


def run(csv_rows: list, steps: int = 120, ablations: bool = True):
    print("\n# Convergence (paper Figs. 1/5/6a): final-loss by precision arm")
    arms = [("bf16", PRESETS["bf16"]), ("fp4", PRESETS["fp4"]),
            ("w4a4_direct", PRESETS["w4a4_direct"])]
    finals = {}
    for name, pol in arms:
        t0 = time.time()
        losses = train_arm(pol, steps)
        finals[name] = _tail_mean(losses)
        dt = time.time() - t0
        print(f"{name:14s} final={finals[name]:.4f}  "
              f"first={losses[0]:.3f}  ({dt:.0f}s, {len(losses)} steps)")
        csv_rows.append((f"convergence/{name}", dt * 1e6 / max(len(losses), 1),
                         f"{finals[name]:.4f}"))
    gap_fp4 = finals["fp4"] - finals["bf16"]
    gap_direct = finals["w4a4_direct"] - finals["bf16"]
    print(f"loss gap: fp4-bf16 = {gap_fp4:+.4f}; "
          f"direct-bf16 = {gap_direct:+.4f}  "
          f"(paper: fp4 gap ~+0.06-0.10, direct-cast much larger/divergent)")
    csv_rows.append(("convergence/fp4_gap", 0.0, f"{gap_fp4:+.4f}"))
    csv_rows.append(("convergence/direct_gap", 0.0, f"{gap_direct:+.4f}"))

    if not ablations:
        return finals
    print("\n# Ablations")
    # Fig. 6b: weight-only W4A8, DGE vs STE
    for name, pol in [("w4a8_dge", PRESETS["w4a8"]),
                      ("w4a8_ste", PRESETS["w4a8_ste"])]:
        f = _tail_mean(train_arm(pol, steps))
        finals[name] = f
        print(f"{name:14s} final={f:.4f}")
        csv_rows.append((f"ablation/{name}", 0.0, f"{f:.4f}"))
    # Fig. 6c: activation-only W8A4, OCC vs direct
    for name, pol in [("w8a4_occ", PRESETS["w8a4"]),
                      ("w8a4_direct", PRESETS["w8a4_direct"])]:
        f = _tail_mean(train_arm(pol, steps))
        finals[name] = f
        print(f"{name:14s} final={f:.4f}")
        csv_rows.append((f"ablation/{name}", 0.0, f"{f:.4f}"))
    # Fig. 6d: granularity
    f = _tail_mean(train_arm(PRESETS["tensor_wise"], steps))
    finals["tensor_wise"] = f
    print(f"{'tensor_wise':14s} final={f:.4f}")
    csv_rows.append(("ablation/tensor_wise", 0.0, f"{f:.4f}"))
    return finals
