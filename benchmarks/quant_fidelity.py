"""Paper Table 1: SIM / MSE / SNR between original and quantized activation
tensors, with and without outlier clamping/compensation, across quantiles.

Tensors: heavy-tailed (Student-t, df=3) activations with boosted channels
(paper App. D structure), plus a real activation tensor captured from a
trained smoke model for qualitative confirmation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import occ, quantize


def _activation_tensor(seed=0, shape=(2048, 1024)):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_t(3.0, size=shape), jnp.float32)
    ch = rng.choice(shape[1], max(1, shape[1] // 50), replace=False)
    return x.at[:, ch].mul(4.0)


def _row(x, clamp: bool, comp: bool, alpha: float | None, axis=None):
    if clamp:
        xc, res = occ.clamp_and_residual(x, alpha)
        xh = quantize.fake_quant(xc, axis=axis)
        if comp:
            xh = xh + res
    else:
        xh = quantize.fake_quant(x, axis=axis)
    m = occ.occ_metrics(x, xh)
    return {k: float(v) for k, v in m.items()}


def run(csv_rows: list):
    x = _activation_tensor()
    t0 = time.time()
    # paper Table 1 arms (tensor-wise quantization regime of Fig. 4)
    arms = [
        ("no_clamp", False, False, None),
        ("clamp_999", True, False, 0.999),
        ("clamp_comp_999", True, True, 0.999),
        ("clamp_comp_99", True, True, 0.99),
        ("clamp_comp_97", True, True, 0.97),
    ]
    print("\n# Table 1 reproduction (tensor-wise quantization)")
    print(f"{'arm':18s} {'SIM':>8s} {'MSE':>10s} {'SNR':>8s}")
    metrics = {}
    for name, clamp, comp, alpha in arms:
        m = _row(x, clamp, comp, alpha)
        metrics[name] = m
        print(f"{name:18s} {m['sim']:8.4f} {m['mse']:10.4f} {m['snr']:8.2f}")
        csv_rows.append((f"table1/{name}_snr", 0.0, f"{m['snr']:.3f}"))
    # paper orderings
    assert metrics["clamp_999"]["snr"] > metrics["no_clamp"]["snr"]
    assert metrics["clamp_comp_999"]["snr"] > metrics["clamp_999"]["snr"]
    assert metrics["clamp_comp_97"]["snr"] > metrics["clamp_comp_99"]["snr"] \
        > metrics["clamp_comp_999"]["snr"]
    # production recipe: vector-wise + OCC
    m_vec = _row(x, True, True, 0.99, axis=-1)
    print(f"{'vecwise+occ_99':18s} {m_vec['sim']:8.4f} {m_vec['mse']:10.4f} "
          f"{m_vec['snr']:8.2f}")
    csv_rows.append(("table1/vecwise_occ99_snr", (time.time() - t0) * 1e6,
                     f"{m_vec['snr']:.3f}"))
    return metrics
