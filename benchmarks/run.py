"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV at the end.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer convergence steps (CI mode)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()

    from benchmarks import (convergence, kernel_bench, quant_fidelity,
                            quant_health, roofline_report, speedup_theory)

    csv_rows: list[tuple[str, float, str]] = []
    benches = {
        "quant_fidelity": lambda: quant_fidelity.run(csv_rows),
        "quant_health": lambda: quant_health.run(csv_rows),
        "speedup_theory": lambda: speedup_theory.run(csv_rows),
        "kernel_bench": lambda: kernel_bench.run(csv_rows),
        "convergence": lambda: convergence.run(
            csv_rows, steps=40 if args.fast else 120,
            ablations=not args.fast),
        "roofline_report": lambda: roofline_report.run(csv_rows),
    }
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"\nFAILED benchmarks: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
