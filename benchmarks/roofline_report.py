"""Aggregate dry-run artifacts into the §Roofline table (EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

COLS = ("arch", "shape", "mesh", "compute_fp4_s", "memory_s", "collective_s",
        "dominant", "useful_ratio", "peak_gb", "mfu_bound")


def load_artifacts(out_dir: str = "artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "skipped": True,
                         "reason": r.get("reason", "")})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "policy": r.get("policy", "fp4"), "skipped": False,
            "compute_bf16_s": r["roofline"]["compute_bf16_s"],
            "compute_fp4_s": r["roofline"]["compute_fp4_s"],
            "memory_s": r["roofline"]["memory_s"],
            "collective_s": r["roofline"]["collective_s"],
            "dominant": r["roofline"]["dominant"],
            "step_time_s": r["roofline"]["step_time_s"],
            "useful_ratio": r["flops"]["useful_ratio"],
            "model_flops_dev": r["flops"]["model_per_dev"],
            "peak_gb": r["memory_analysis"]["peak_estimate_gb"],
            "mfu_bound": r["mfu_bound"],
            "wire_gb": r["collectives"]["total_wire_bytes"] / 1e9,
            "compile_s": r["compile_s"],
        })
    return rows


def render_table(rows) -> str:
    lines = ["| arch | shape | mesh | compute(s) | memory(s) | coll(s) | "
             "dominant | useful | peak GB | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | skipped | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_fp4_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['peak_gb']:.1f} | "
            f"{r['mfu_bound']:.3f} |")
    return "\n".join(lines)


def run(csv_rows: list, out_dir: str = "artifacts/dryrun"):
    rows = load_artifacts(out_dir)
    done = [r for r in rows if not r.get("skipped")]
    skipped = [r for r in rows if r.get("skipped")]
    print(f"\n# Roofline report: {len(done)} cells analysed, "
          f"{len(skipped)} skipped")
    if not done:
        print("(no artifacts yet -- run launch/sweep.py)")
        return
    print(render_table(rows))
    for r in done:
        csv_rows.append((f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
                         r["step_time_s"] * 1e6,
                         f"{r['dominant']}:{r['mfu_bound']:.3f}"))
