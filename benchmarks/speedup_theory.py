"""Paper Appendix B: theoretical speedup + overhead accounting, reproduced
symbolically and evaluated at the paper's reference point (h=4096, s=2048,
alpha=0.99), plus the TPU-adaptation column (int8 MXU = 2x GeMM throughput
instead of Blackwell's 4x).
"""
from __future__ import annotations

import time


def component_table(h: int, s: int):
    """FLOP breakdown per Transformer layer (paper Table 5), per (b=1)."""
    rows = [
        ("input_layernorm", 4 * s * h, 1.0),
        ("qkv_projection", 6 * s * h * h, 4.0),
        ("attention_scores", 4 * s * s * h, 1.0),
        ("softmax", s * s * h, 1.0),
        ("output_projection", 2 * s * h * h, 4.0),
        ("post_attn_layernorm", 4 * s * h, 1.0),
        ("ffn_up", 8 * s * h * h, 4.0),
        ("gelu", 28 * s * h, 1.0),
        ("ffn_down", 8 * s * h * h, 4.0),
    ]
    return rows


def speedups(h: int = 4096, s: int = 2048, alpha: float = 0.99,
             gemm_speedup: float = 4.0):
    """Returns (ideal, adjusted) speedup per paper App. B formulas,
    parameterized by the hardware GeMM speedup (4x B200 FP4-vs-FP32-ish,
    2x TPU int8-vs-bf16)."""
    total_fp32 = 24 * h + 5 * s + 36
    gemm_term = 24 * h / gemm_speedup
    ideal = total_fp32 / (gemm_term + 5 * s + 36)
    # DGE: +8 flops/elem over 12*b*s*h gemm inputs -> 96bsh per iter (/3 fwd)
    # OCC: 2(1-alpha) * 12bsh^2 extra dense-equivalent flops
    adjusted = total_fp32 / (gemm_term + 24 * (1 - alpha) * h + 5 * s +
                             36 + 32)
    return ideal, adjusted


def run(csv_rows: list):
    t0 = time.time()
    print("\n# Appendix B: FLOP breakdown (h=4096, s=2048, per layer, b=1)")
    print(f"{'component':22s} {'FLOPs(FP32)':>14s} {'speedup':>8s}")
    for name, flops, sp in component_table(4096, 2048):
        print(f"{name:22s} {flops:14.3e} {sp:8.1f}x")

    ideal_paper, adj_paper = speedups(gemm_speedup=4.0)
    print(f"\npaper (Blackwell FP4, 4x GeMM): ideal {ideal_paper:.2f}x, "
          f"DGE+OCC adjusted {adj_paper:.2f}x  (paper reports 3.12 / 2.95)")
    assert abs(ideal_paper - 3.12) < 0.02
    # NOTE: evaluating the paper's own App. B formula
    # (24h+5s+36)/(6h+24(1-a)h+5s+68) at h=4096,s=2048,a=0.99 gives 3.03,
    # not the 2.95 printed in the paper -- a small arithmetic slip in the
    # paper; we reproduce the formula, not the typo (EXPERIMENTS.md).
    assert abs(adj_paper - 3.03) < 0.02
    ideal_tpu, adj_tpu = speedups(gemm_speedup=2.0)
    print(f"TPU adaptation (int8 MXU, 2x GeMM): ideal {ideal_tpu:.2f}x, "
          f"adjusted {adj_tpu:.2f}x")
    csv_rows.append(("speedup/paper_ideal", 0.0, f"{ideal_paper:.3f}"))
    csv_rows.append(("speedup/paper_adjusted", 0.0, f"{adj_paper:.3f}"))
    csv_rows.append(("speedup/tpu_ideal", 0.0, f"{ideal_tpu:.3f}"))
    csv_rows.append(("speedup/tpu_adjusted",
                     (time.time() - t0) * 1e6, f"{adj_tpu:.3f}"))

    # overhead shares (paper: DGE 0.1%, OCC 5.6%)
    h, s, alpha = 4096, 2048, 0.99
    dge_share = 32 / (6 * h + 5 * s + 36)
    occ_share = 24 * (1 - alpha) * h / (6 * h + 5 * s + 36)
    print(f"overheads: DGE {dge_share*100:.2f}% (paper 0.1%), "
          f"OCC {occ_share*100:.2f}% (paper 5.6%)")
    csv_rows.append(("speedup/dge_overhead_pct", 0.0, f"{dge_share*100:.3f}"))
    csv_rows.append(("speedup/occ_overhead_pct", 0.0, f"{occ_share*100:.3f}"))
