"""Quant-health columns for the BENCH report (DESIGN.md §11).

Runs the repro.obs collection pipeline over the same heavy-tailed
activation tensor as quant_fidelity and over each FP4 format's weight
path, emitting the health vocabulary the training JSONL uses:
clamp_frac / residual_mass / underflow_frac / snr_db / scale range.
This is the static counterpart of the per-step health log -- handy for
eyeballing what "healthy" numbers look like before a long run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import formats, occ, quantize


def _activation_tensor(seed=0, shape=(2048, 1024)):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_t(3.0, size=shape), jnp.float32)
    ch = rng.choice(shape[1], max(1, shape[1] // 50), replace=False)
    return x.at[:, ch].mul(4.0)


def _health(x, fmt, alpha):
    """clamp+quantize x and harvest the obs record set on host."""
    with obs.collect() as col:
        xc, res = occ.clamp_and_residual(x, alpha)
        obs.record_clamp(x, res)
        q, scale = quantize.quantize(xc, axis=-1, fmt=fmt)
        obs.record_scale("act", xc, scale, axis=-1)
        obs.record_quant_error("act", xc, q, scale)
        rec = col.harvest()
    return {k: float(v) for k, v in jax.device_get(rec).items()}


def run(csv_rows: list):
    x = _activation_tensor()
    print("\n# Quant-health vocabulary (obs pipeline, alpha=0.99)")
    print(f"{'fmt':8s} {'clamp%':>8s} {'resid':>8s} {'undfl%':>8s} "
          f"{'snr_db':>8s} {'scl_min':>9s} {'scl_max':>9s}")
    for name, fmt in [("e2m1", formats.E2M1), ("e1m2", formats.E1M2)]:
        t0 = time.time()
        h = _health(x, fmt, 0.99)
        us = (time.time() - t0) * 1e6
        cf = h["clamp_frac"]
        rm = h["residual_mass"]
        uf = h["act/underflow_frac"]
        snr = h["act/snr_db"]
        smin, smax = h["act/scale_min"], h["act/scale_max"]
        print(f"{name:8s} {100 * cf:8.3f} {rm:8.4f} {100 * uf:8.3f} "
              f"{snr:8.2f} {smin:9.3g} {smax:9.3g}")
        csv_rows.append((f"health/{name}_clamp_frac", us, f"{cf:.5f}"))
        csv_rows.append((f"health/{name}_snr_db", 0.0, f"{snr:.3f}"))
        csv_rows.append((f"health/{name}_underflow_frac", 0.0, f"{uf:.5f}"))
        # healthy-tensor sanity: quantizing a well-scaled activation should
        # clear the sentinel defaults (SentinelConfig) by a wide margin
        assert snr > 6.0, snr
        assert uf < 0.01, uf
    # degenerate tensor: everything underflows -> underflow_frac == 1
    tiny = jnp.full((64, 64), 1e-33, jnp.float32)
    h = _health(tiny, formats.E2M1, 0.99)
    assert h["act/underflow_frac"] == 1.0, h
    csv_rows.append(("health/underflow_sentinel", 0.0,
                     f"{h['act/underflow_frac']:.1f}"))
    return None
