"""Kernel micro-benchmarks.

Wall-time on this host measures the *simulation* (CPU, interpret-mode
Pallas), so two complementary numbers are reported per kernel:
  * CPU wall-time of the pure-jnp pipeline (simulation cost, paper §6
    'simulations ... significantly prolong runtime'),
  * projected TPU v5e time from the kernel's bytes/FLOPs roofline
    (HBM 819 GB/s, bf16 197 / int8 394 TFLOP/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.core.fp4_gemm import fp4_matmul
from repro.core.policy import FP4_PAPER, BF16

HBM = 819e9
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(csv_rows: list):
    print("\n# Kernel benchmarks (CPU simulation walltime + v5e projection)")
    M, K, N = 2048, 4096, 4096
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.bfloat16)

    t_bf16 = _time(jax.jit(lambda a, w: a @ w), a, w)
    pol = FP4_PAPER.replace(occ=False)
    t_fp4 = _time(jax.jit(lambda a, w: fp4_matmul(a, w, pol)), a, w)
    pol_occ = FP4_PAPER.replace(occ_threshold="sample")
    from repro.core.linear import fp4_linear
    t_occ = _time(jax.jit(lambda a, w: fp4_linear(a, w, policy=pol_occ)), a, w)
    print(f"gemm {M}x{K}x{N}: bf16 {t_bf16:.0f}us | fp4-sim {t_fp4:.0f}us "
          f"({t_fp4/t_bf16:.1f}x sim overhead) | +occ {t_occ:.0f}us")
    csv_rows.append(("kernel/gemm_bf16_cpu", t_bf16, "us"))
    csv_rows.append(("kernel/gemm_fp4sim_cpu", t_fp4,
                     f"{t_fp4/t_bf16:.2f}x_overhead"))

    # v5e projections
    flops = 2.0 * M * K * N
    bytes_bf16 = 2.0 * (M * K + K * N + M * N)
    bytes_fp4 = 0.5 * (M * K + K * N) + 2.0 * M * N  # 4-bit operands
    t_proj_bf16 = max(flops / PEAK_BF16, bytes_bf16 / HBM) * 1e6
    t_proj_fp4 = max(flops / PEAK_INT8, bytes_fp4 / HBM) * 1e6
    print(f"v5e projection: bf16 {t_proj_bf16:.1f}us, fp4-int8 "
          f"{t_proj_fp4:.1f}us ({t_proj_bf16/t_proj_fp4:.2f}x speedup)")
    csv_rows.append(("kernel/gemm_v5e_bf16_proj", t_proj_bf16, "us"))
    csv_rows.append(("kernel/gemm_v5e_fp4_proj", t_proj_fp4,
                     f"{t_proj_bf16/t_proj_fp4:.2f}x"))

    # quantize kernel: bytes-bound
    q_bytes = 2.0 * M * K + 0.5 * M * K + 4.0 * M
    t_q = q_bytes / HBM * 1e6
    print(f"fp4_quant v5e projection ({M}x{K}): {t_q:.1f}us "
          f"(pure bandwidth, {q_bytes/1e6:.1f} MB)")
    csv_rows.append(("kernel/quant_v5e_proj", t_q, "bandwidth_bound"))

    # flash attention: HBM traffic vs materialized scores
    B, S, H, D = 8, 4096, 16, 128
    naive_bytes = 4.0 * B * H * S * S * 2  # scores + probs, bf16
    flash_bytes = 2.0 * B * S * H * D * 4  # q,k,v,o once
    print(f"flash-attn traffic {B}x{S}x{H}x{D}: naive {naive_bytes/1e9:.1f} GB"
          f" -> flash {flash_bytes/1e9:.2f} GB "
          f"({naive_bytes/flash_bytes:.0f}x reduction)")
    csv_rows.append(("kernel/flash_traffic_reduction", 0.0,
                     f"{naive_bytes/flash_bytes:.1f}x"))
