"""Kernel micro-benchmarks.

Wall-time on this host measures the *simulation* (CPU, interpret-mode
Pallas), so two complementary numbers are reported per kernel:
  * CPU wall-time of the pure-jnp pipeline (simulation cost, paper §6
    'simulations ... significantly prolong runtime'),
  * projected TPU v5e time from the kernel's bytes/FLOPs roofline
    (HBM 819 GB/s, bf16 197 / int8 394 TFLOP/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.core.fp4_gemm import fp4_matmul
from repro.core.policy import FP4_PAPER, BF16

HBM = 819e9
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12

# Fused-vs-split comparison shapes: one MXU-aligned, one skinny-M, one
# deliberately ragged (nothing divides the default blocks).
FUSED_SHAPES = [(256, 512, 256), (128, 384, 512), (320, 192, 160)]


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(csv_rows: list):
    print("\n# Kernel benchmarks (CPU simulation walltime + v5e projection)")
    M, K, N = 2048, 4096, 4096
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.bfloat16)

    t_bf16 = _time(jax.jit(lambda a, w: a @ w), a, w)
    pol = FP4_PAPER.replace(occ=False)
    t_fp4 = _time(jax.jit(lambda a, w: fp4_matmul(a, w, pol)), a, w)
    pol_occ = FP4_PAPER.replace(occ_threshold="sample")
    from repro.core.linear import fp4_linear
    t_occ = _time(jax.jit(lambda a, w: fp4_linear(a, w, policy=pol_occ)), a, w)
    print(f"gemm {M}x{K}x{N}: bf16 {t_bf16:.0f}us | fp4-sim {t_fp4:.0f}us "
          f"({t_fp4/t_bf16:.1f}x sim overhead) | +occ {t_occ:.0f}us")
    csv_rows.append(("kernel/gemm_bf16_cpu", t_bf16, "us"))
    csv_rows.append(("kernel/gemm_fp4sim_cpu", t_fp4,
                     f"{t_fp4/t_bf16:.2f}x_overhead"))

    # v5e projections
    flops = 2.0 * M * K * N
    bytes_bf16 = 2.0 * (M * K + K * N + M * N)
    bytes_fp4 = 0.5 * (M * K + K * N) + 2.0 * M * N  # 4-bit operands
    t_proj_bf16 = max(flops / PEAK_BF16, bytes_bf16 / HBM) * 1e6
    t_proj_fp4 = max(flops / PEAK_INT8, bytes_fp4 / HBM) * 1e6
    print(f"v5e projection: bf16 {t_proj_bf16:.1f}us, fp4-int8 "
          f"{t_proj_fp4:.1f}us ({t_proj_bf16/t_proj_fp4:.2f}x speedup)")
    csv_rows.append(("kernel/gemm_v5e_bf16_proj", t_proj_bf16, "us"))
    csv_rows.append(("kernel/gemm_v5e_fp4_proj", t_proj_fp4,
                     f"{t_proj_bf16/t_proj_fp4:.2f}x"))

    # quantize kernel: bytes-bound
    q_bytes = 2.0 * M * K + 0.5 * M * K + 4.0 * M
    t_q = q_bytes / HBM * 1e6
    print(f"fp4_quant v5e projection ({M}x{K}): {t_q:.1f}us "
          f"(pure bandwidth, {q_bytes/1e6:.1f} MB)")
    csv_rows.append(("kernel/quant_v5e_proj", t_q, "bandwidth_bound"))

    # flash attention: HBM traffic vs materialized scores
    B, S, H, D = 8, 4096, 16, 128
    naive_bytes = 4.0 * B * H * S * S * 2  # scores + probs, bf16
    flash_bytes = 2.0 * B * S * H * D * 4  # q,k,v,o once
    print(f"flash-attn traffic {B}x{S}x{H}x{D}: naive {naive_bytes/1e9:.1f} GB"
          f" -> flash {flash_bytes/1e9:.2f} GB "
          f"({naive_bytes/flash_bytes:.0f}x reduction)")
    csv_rows.append(("kernel/flash_traffic_reduction", 0.0,
                     f"{naive_bytes/flash_bytes:.1f}x"))

    fused_vs_split(csv_rows)


def _traffic_model(M: int, K: int, N: int):
    """Per-pipeline HBM bytes over the activation path (DESIGN.md §12).

    Split (clamp kernel -> quant kernel -> GeMM): A crosses HBM three
    times plus the intermediate writes -- clamp r2+w2, quant r2+w0.5,
    GeMM r0.5 = 7 B/elt. Fused: scale pre-pass r2 (writes only M floats),
    fused GeMM r2 (raw bf16 A, quantized in VMEM) = 4 B/elt. Weights
    (0.5 B/elt codes) + scales + f32 output are identical on both sides.
    """
    common = 0.5 * K * N + 4.0 * (M + N) + 4.0 * M * N
    split = 7.0 * M * K + 0.5 * M * K + common  # + A_q GeMM-side read
    fused = 4.0 * M * K + 4.0 * M + common      # + sa re-read by the GeMM
    return split, fused


def fused_vs_split(csv_rows: list):
    """Fused single-pass pipeline vs the split clamp->quant->GeMM kernels:
    CPU interpret walltime (simulation cost) and the v5e HBM projection."""
    from repro.kernels import autotune, ops

    print("\n# fused vs split FP4 pipeline "
          "(CPU interpret walltime | v5e HBM-traffic projection)")
    key = jax.random.PRNGKey(0)
    for M, K, N in FUSED_SHAPES:
        k1, k2 = jax.random.split(jax.random.fold_in(key, M + N))
        a = jax.random.normal(k1, (M, K), jnp.float32)
        w = jax.random.normal(k2, (K, N), jnp.float32)
        sw = quantize.absmax_scale(w, 0, 6.0)
        w_q = quantize.lut_round(w * sw)

        def split_pipe(a):
            a_c, _ = ops.outlier_clamp(a, -3.0, 3.0)
            a_q, sa = ops.fp4_quantize(a_c)
            return ops.fp4_matmul_pallas(a_q, w_q, sa, sw)

        def fused_pipe(a):
            lohi = jnp.asarray([[-3.0, 3.0]], jnp.float32)
            sa = ops.fused_row_scale(a, lohi)
            return ops.fp4_matmul_fused(a, w_q, sa, sw, lohi)

        t_split = _time(split_pipe, a, iters=2)
        t_fused = _time(fused_pipe, a, iters=2)
        b_split, b_fused = _traffic_model(M, K, N)
        p_split = b_split / HBM * 1e6
        p_fused = b_fused / HBM * 1e6
        tag = f"{M}x{K}x{N}"
        print(f"  {tag:>13}: cpu split {t_split:.0f}us fused {t_fused:.0f}us"
              f" | v5e traffic {b_split/1e6:.2f} -> {b_fused/1e6:.2f} MB"
              f" ({b_split/b_fused:.2f}x less, {p_split:.1f} -> "
              f"{p_fused:.1f}us)")
        csv_rows.append((f"kernel/fused_cpu_{tag}", t_fused,
                         f"split_{t_split:.0f}us"))
        csv_rows.append((f"kernel/fused_v5e_traffic_{tag}", p_fused,
                         f"{b_split/b_fused:.2f}x_less_than_split"))

    # Persist tuned blocks for the comparison shapes (exercises the
    # autotuner end-to-end; CI uploads the resulting JSON artifact).
    M, K, N = FUSED_SHAPES[-1]
    a = jax.random.normal(jax.random.PRNGKey(2), (M, K), jnp.float32)
    w_q = quantize.lut_round(jnp.clip(
        jax.random.normal(jax.random.PRNGKey(3), (K, N)), -6, 6))
    sw = jnp.ones((1, N), jnp.float32)
    sa = ops.fused_row_scale(a, jnp.asarray([[-3.0, 3.0]], jnp.float32))
    lohi = jnp.asarray([[-3.0, 3.0]], jnp.float32)

    def make_fn(bm, bn, bk):
        def fn():
            out = ops.fp4_matmul_fused(a, w_q, sa, sw, lohi,
                                       blocks=(bm, bn, bk))
            jax.block_until_ready(out)
        return fn

    best, best_t = autotune.autotune(
        "fused_fwd", make_fn, M, N, K, iters=1,
        candidates=[(64, 64, 64), (128, 128, 128), (128, 128, 256)])
    print(f"  autotune fused_fwd {M}x{N}x{K}: best blocks {best} "
          f"({best_t*1e6:.0f}us) -> {autotune.default_cache_path()}")
    csv_rows.append((f"kernel/autotune_fused_fwd_{M}x{N}x{K}",
                     best_t * 1e6, f"blocks_{best[0]}x{best[1]}x{best[2]}"))


if __name__ == "__main__":
    rows: list = []
    fused_vs_split(rows)
    print("\ncsv:")
    for name, val, note in rows:
        print(f"{name},{val:.3f},{note}")
