"""Input-pipeline columns for the BENCH report (DESIGN.md §14).

Measures tokens/s into the device and per-step input stall for three
arms over the same shard corpus:

  * blocking   -- PackedStream consumed inline (the pre-v2 pattern: the
                  step waits for shard reads + packing on the critical
                  path).
  * prefetch   -- the same stream behind DevicePrefetcher (host packing
                  and H2D staging overlap the step).
  * synthetic  -- SyntheticStream baseline (no disk, generation cost
                  only), for calibrating how much of the stall is I/O.

The "step" is a jitted matmul stack sized by --step-ms so the bench
reflects overlap against a realistic device occupancy, not an empty
loop. Stall is time the consumer spends blocked acquiring the next
batch; overlap = 1 - stall/step_wall.

    PYTHONPATH=src:. python benchmarks/data_bench.py [--fast] \
        [--json data_bench.json]
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (DataConfig, DevicePrefetcher, PackedStream,
                        ShardReader, SyntheticLM, SyntheticStream,
                        write_synthetic_shards)


def make_step(d: int, iters: int):
    """A jitted device workload with tunable duration (matmul chain)."""
    @jax.jit
    def step(x, tokens):
        s = jnp.sum(tokens).astype(jnp.float32) * 1e-9
        for _ in range(iters):
            x = jnp.tanh(x @ x) + s
        return x
    return step


def bench_loader(loader, step_fn, x0, n_steps: int) -> dict:
    """Drive `n_steps` (fetch -> step -> block) iterations; time the parts."""
    x = x0
    stall = 0.0
    tokens = 0
    t_start = time.perf_counter()
    for _ in range(n_steps):
        t0 = time.perf_counter()
        pb = loader.next_batch()
        toks = pb.arrays["tokens"]
        stall += time.perf_counter() - t0
        tokens += int(np.asarray(toks).size * pb.meta.get("pack_frac", 1.0))
        x = step_fn(x, jnp.asarray(toks))
        x.block_until_ready()
    wall = time.perf_counter() - t_start
    return {"wall_s": wall, "stall_ms_per_step": stall / n_steps * 1e3,
            "tokens_per_s": tokens / wall,
            "overlap": max(0.0, 1.0 - stall / wall)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small corpus / few steps (CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--step-ms", type=float, default=20.0,
                    help="target simulated device step duration")
    args = ap.parse_args()

    n_docs = 400 if args.fast else 4000
    n_steps = 30 if args.fast else 200
    cfg = DataConfig(vocab_size=4096, seq_len=args.seq,
                     global_batch=args.batch, seed=0)
    root = tempfile.mkdtemp(prefix="data_bench_")
    try:
        manifest = write_synthetic_shards(root, cfg, n_docs,
                                          mean_len=300.0,
                                          shard_tokens=1 << 20)
        reader = ShardReader(manifest)

        # calibrate the fake device step towards --step-ms
        d, iters = 256, 4
        step_fn = make_step(d, iters)
        x0 = jnp.ones((d, d), jnp.float32)
        dummy = jnp.zeros((args.batch, args.seq), jnp.int32)
        step_fn(x0, dummy).block_until_ready()
        t0 = time.perf_counter()
        step_fn(x0, dummy).block_until_ready()
        base_ms = (time.perf_counter() - t0) * 1e3
        iters = max(1, int(iters * args.step_ms / max(base_ms, 1e-3)))
        step_fn = make_step(d, iters)
        step_fn(x0, dummy).block_until_ready()

        def shard_stream():
            return PackedStream(reader, seq_len=args.seq,
                                batch_size=args.batch, seed=1)

        arms = {}
        arms["blocking"] = bench_loader(shard_stream(), step_fn, x0, n_steps)
        pf = DevicePrefetcher(shard_stream(),
                              place_fn=lambda a: {k: jnp.asarray(v)
                                                  for k, v in a.items()},
                              depth=2)
        try:
            arms["prefetch"] = bench_loader(pf, step_fn, x0, n_steps)
            arms["prefetch"].update(pf.stats())
        finally:
            pf.stop()
        arms["synthetic"] = bench_loader(
            SyntheticStream(SyntheticLM(cfg)), step_fn, x0, n_steps)

        print(f"BENCH data pipeline: seq={args.seq} batch={args.batch} "
              f"steps={n_steps} corpus={reader.total_tokens/1e6:.1f}M tok")
        hdr = (f"{'arm':<10} {'tok/s':>12} {'stall ms/step':>14} "
               f"{'overlap':>8}")
        print(hdr)
        for name, r in arms.items():
            print(f"{name:<10} {r['tokens_per_s']:>12.0f} "
                  f"{r['stall_ms_per_step']:>14.3f} {r['overlap']:>8.3f}")
        speed = (arms['blocking']['stall_ms_per_step'] /
                 max(arms['prefetch']['stall_ms_per_step'], 1e-6))
        print(f"prefetch stall reduction: {speed:.1f}x "
              f"({arms['blocking']['stall_ms_per_step']:.2f}ms -> "
              f"{arms['prefetch']['stall_ms_per_step']:.2f}ms per step)")

        if args.json:
            with open(args.json, "w") as f:
                json.dump({"config": vars(args), "arms": arms}, f, indent=1)
            print(f"wrote {args.json}")
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
