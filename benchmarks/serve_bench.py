"""Serve-engine throughput/latency columns for the BENCH report
(DESIGN.md §13).

Drives the continuous-batching ServeEngine at batch sizes {1, 8, 32}
(oversubscribed ~1.5x so admission/queueing is exercised) and reports
tokens/s plus p50/p99 time-to-first-token per configuration, paged and
dense. Numbers from the CPU-sim smoke model calibrate the *engine
overhead* (scheduling, page bookkeeping, host<->device sync), not model
FLOPs.

    PYTHONPATH=src:. python benchmarks/serve_bench.py [--fast] \
        [--json serve_bench.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.models import build_model
from repro.serve import ServeEngine

BATCH_SIZES = (1, 8, 32)


def _bench_one(model, params, *, n_slots: int, n_requests: int,
               prompt_len: int, gen_len: int, paged: bool,
               page_size: int = 16, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    eng = ServeEngine(model, params, n_slots=n_slots,
                      max_len=prompt_len + gen_len + 2,
                      prefill_len=prompt_len, paged=paged,
                      page_size=page_size)
    # warmup: compile prefill + decode once, outside the timed region
    wid = eng.submit(rng.integers(1, model.cfg.vocab_size,
                                  size=prompt_len).tolist(), 2)
    eng.run()
    assert eng.poll(wid)["state"] == "done"

    prompts = [rng.integers(1, model.cfg.vocab_size,
                            size=int(rng.integers(prompt_len // 2,
                                                  prompt_len + 1))).tolist()
               for _ in range(n_requests)]
    t0 = time.monotonic()
    rids = [eng.submit(p, gen_len) for p in prompts]
    res = eng.run()
    wall = time.monotonic() - t0
    eng.check_invariants()
    assert all(res[r]["state"] == "done" for r in rids)

    total_tokens = sum(len(res[r]["tokens"]) for r in rids)
    ttfts = np.asarray([eng.poll(r)["ttft_s"] for r in rids])
    return {
        "mode": "paged" if paged else "dense",
        "n_slots": n_slots, "n_requests": n_requests,
        "gen_len": gen_len, "engine_steps": eng.step_count,
        "wall_s": wall, "tokens": total_tokens,
        "tok_per_s": total_tokens / wall,
        "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttfts, 99) * 1e3),
    }


def run(csv_rows: list, *, arch: str = "llama2-400m", fast: bool = False,
        prompt_len: int = 16, gen_len: int = 8) -> list[dict]:
    cfg = get_config(arch, smoke=True).replace(cache_dtype="float32",
                                               remat=False)
    model = build_model(cfg, get_policy("fp4").replace(occ=False))
    params, _ = model.init(jax.random.PRNGKey(0))

    sizes = BATCH_SIZES[:2] if fast else BATCH_SIZES
    rows = []
    print(f"\n# Serve engine throughput/latency ({arch} smoke, fp4 occ=off, "
          f"prompt<=~{prompt_len}, gen={gen_len})")
    print(f"{'mode':6s} {'slots':>5s} {'reqs':>5s} {'steps':>6s} "
          f"{'tok/s':>9s} {'ttft_p50_ms':>12s} {'ttft_p99_ms':>12s}")
    for paged in (True, False):
        for b in sizes:
            r = _bench_one(model, params, n_slots=b,
                           n_requests=max(b + b // 2, b + 1),
                           prompt_len=prompt_len, gen_len=gen_len,
                           paged=paged)
            rows.append(r)
            print(f"{r['mode']:6s} {r['n_slots']:5d} {r['n_requests']:5d} "
                  f"{r['engine_steps']:6d} {r['tok_per_s']:9.1f} "
                  f"{r['ttft_p50_ms']:12.1f} {r['ttft_p99_ms']:12.1f}")
            tag = f"serve/{r['mode']}_b{b}"
            csv_rows.append((f"{tag}_tok_per_s", 1e6 / max(r["tok_per_s"],
                                                           1e-9),
                             f"{r['tok_per_s']:.1f}"))
            csv_rows.append((f"{tag}_ttft_p50_ms", 0.0,
                             f"{r['ttft_p50_ms']:.1f}"))
            csv_rows.append((f"{tag}_ttft_p99_ms", 0.0,
                             f"{r['ttft_p99_ms']:.1f}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-400m")
    ap.add_argument("--fast", action="store_true",
                    help="batch sizes {1,8} only (CI smoke)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--json", default=None,
                    help="write per-config rows to this JSON file")
    args = ap.parse_args()

    csv_rows: list = []
    rows = run(csv_rows, arch=args.arch, fast=args.fast,
               prompt_len=args.prompt_len, gen_len=args.gen_len)
    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
