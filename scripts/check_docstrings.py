#!/usr/bin/env python
"""Docstring lint for the public `repro.core` API (stdlib-only fallback).

CI runs `ruff check --select D` (pydocstyle rules, configured in
pyproject.toml) when ruff is installed; this script enforces the same
missing-docstring subset (D100/D101/D102/D103) with nothing but the
stdlib, so bare environments (and the pre-commit habit of running
`python scripts/check_docstrings.py`) get the same gate.

Checked, per module under src/repro/core:
  * module docstring present (D100)
  * every public class has a docstring (D101)
  * every public function/method has a docstring (D102/D103),
    ignoring names with a leading underscore and dunder methods
    other than __init__ (property setters/overloads included)

Exit code 0 = clean; 1 = violations (listed one per line as
path:line: code name).
"""
from __future__ import annotations

import ast
import pathlib
import sys

DEFAULT_SCOPE = "src/repro/core"


def _public(name: str) -> bool:
    return not name.startswith("_")


def _check_module(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text(), filename=str(path))
    errs = []
    if ast.get_docstring(tree) is None:
        errs.append(f"{path}:1: D100 missing module docstring")

    def walk(node, in_class: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                if _public(child.name) and \
                        ast.get_docstring(child) is None:
                    errs.append(f"{path}:{child.lineno}: D101 missing "
                                f"docstring in class {child.name}")
                walk(child, in_class=True)
            elif isinstance(child, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                if _public(child.name) and \
                        ast.get_docstring(child) is None:
                    code = "D102" if in_class else "D103"
                    errs.append(f"{path}:{child.lineno}: {code} missing "
                                f"docstring in {child.name}")
                # nested defs are implementation detail: skip
    walk(tree, in_class=False)
    return errs


def main(argv: list[str]) -> int:
    scope = pathlib.Path(argv[1] if len(argv) > 1 else DEFAULT_SCOPE)
    files = sorted(scope.rglob("*.py"))
    if not files:
        print(f"no python files under {scope}", file=sys.stderr)
        return 2
    errs = []
    for f in files:
        errs.extend(_check_module(f))
    for e in errs:
        print(e)
    print(f"{len(files)} files checked, {len(errs)} violations")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
