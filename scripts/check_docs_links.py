#!/usr/bin/env python
"""Internal-link checker for the repo's markdown docs (stdlib-only).

Validates every relative markdown link in README.md, DESIGN.md and
docs/**.md:

  * the target file exists (relative to the linking file)
  * a `#fragment` resolves to a heading in the target, using GitHub's
    slug rules (lowercase, punctuation stripped, spaces -> dashes)

External links (http/https/mailto) are ignored -- CI must not depend on
network reachability. Exit 0 = clean, 1 = broken links listed.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
ROOT = pathlib.Path(__file__).resolve().parent.parent


def github_slug(heading: str) -> str:
    """GitHub's anchor algorithm: strip formatting/punctuation, dash spaces."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)          # drop punctuation (unicode-aware)
    return h.replace(" ", "-")


def doc_files() -> list[pathlib.Path]:
    files = [ROOT / "README.md", ROOT / "DESIGN.md"]
    files += sorted((ROOT / "docs").rglob("*.md")) \
        if (ROOT / "docs").is_dir() else []
    return [f for f in files if f.exists()]


def anchors_of(path: pathlib.Path) -> set[str]:
    return {github_slug(m.group(1))
            for m in HEADING_RE.finditer(path.read_text())}


def main() -> int:
    errs = []
    for src in doc_files():
        text = src.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = src if not path_part else \
                (src.parent / path_part).resolve()
            line = text[:m.start()].count("\n") + 1
            if not dest.exists():
                errs.append(f"{src.relative_to(ROOT)}:{line}: broken link "
                            f"-> {target} (no such file)")
                continue
            if frag and dest.suffix == ".md" and \
                    frag not in anchors_of(dest):
                errs.append(f"{src.relative_to(ROOT)}:{line}: broken "
                            f"anchor -> {target}")
    for e in errs:
        print(e)
    print(f"{len(doc_files())} files checked, {len(errs)} broken links")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
