"""Continuous-batching serving demo: submit a stream of ragged prompts to
the ServeEngine (slot scheduler + paged fp8-capable KV cache), poll while
it drains, and print per-request results with TTFT.

    PYTHONPATH=src python examples/serve_decode.py [--arch llama2-400m]
        [--dense] [--obs serve_health.jsonl]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.models import build_model
from repro.obs import JsonlWriter
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-400m")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dense", action="store_true",
                    help="ring cache instead of paged KV")
    ap.add_argument("--obs", default=None,
                    help="write per-slot decode-health JSONL here")
    args = ap.parse_args()

    # remat off: obs suspends collection inside remat regions, and full
    # per-layer decode-health telemetry needs the unrolled execution mode
    # (DESIGN.md §11); serving never rematerializes anyway.
    cfg = get_config(args.arch, smoke=True).replace(remat=False)
    pol = get_policy("fp4").replace(occ_threshold="exact",
                                    obs_metrics=bool(args.obs))
    model = build_model(cfg, pol)
    params, _ = model.init(jax.random.PRNGKey(0))

    writer = JsonlWriter(args.obs) if args.obs else None
    eng = ServeEngine(model, params, n_slots=args.slots,
                      max_len=args.prompt_len + args.gen_len + 4,
                      prefill_len=args.prompt_len, paged=not args.dense,
                      page_size=args.page_size, obs_writer=writer)

    rng = np.random.default_rng(0)
    rids = []
    for i in range(args.requests):
        n = int(rng.integers(args.prompt_len // 3, args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, size=n).tolist()
        gen = int(rng.integers(args.gen_len // 2, args.gen_len + 1))
        rids.append(eng.submit(prompt, gen))

    mode = "dense ring" if args.dense else f"paged (page_size={args.page_size})"
    print(f"arch={args.arch} (smoke config), {mode}, "
          f"{args.requests} requests / {args.slots} slots")
    t0 = time.monotonic()
    while eng.busy:
        eng.step()
        running = sum(eng.poll(r)["state"] == "running" for r in rids)
        done = sum(eng.poll(r)["state"] == "done" for r in rids)
        print(f"\rstep {eng.step_count:4d}  running={running}  "
              f"done={done}/{len(rids)}", end="", flush=True)
    dt = time.monotonic() - t0
    print()

    total = 0
    for rid in rids:
        st = eng.poll(rid)
        total += len(st["tokens"])
        ttft = f"{st['ttft_s'] * 1e3:6.1f}ms" if st["ttft_s"] else "   n/a"
        print(f"  req {rid}: {st['state']:7s} ttft={ttft} "
              f"tokens={st['tokens'][:8]}{'...' if len(st['tokens']) > 8 else ''}")
    print(f"{total} tokens in {dt:.1f}s ({total / dt:.1f} tok/s on CPU sim)")
    eng.check_invariants()
    if writer:
        writer.close()
        print(f"decode-health records -> {args.obs}")


if __name__ == "__main__":
    main()
