"""Batched serving demo: prefill a prompt batch through an FP4 model, then
greedy-decode continuations against the KV cache (ring buffers for local
layers, fp8 cache optional).

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.models import build_model
from repro.serve.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg, get_policy("fp4").replace(occ_threshold="exact"))
    params, _ = model.init(jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 1,
                                 cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(model, params, {"tokens": prompts},
                          steps=args.gen_len,
                          max_len=args.prompt_len + args.gen_len + 4)
    dt = time.time() - t0
    print(f"arch={args.arch} (smoke config), batch={args.batch}")
    print(f"prompt[0]: {prompts[0, :8].tolist()}...")
    print(f"generated[0]: {out[0].tolist()}")
    total = args.batch * args.gen_len
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s on CPU sim)")


if __name__ == "__main__":
    main()
