"""End-to-end driver: train a ~100M-param LLaMA with the full production
stack -- FP4 policy, mixed-precision Adam, warmup+cosine schedule, atomic
checkpointing with resume, NaN guards, straggler watchdog.

    PYTHONPATH=src python examples/train_llama_fp4.py \
        [--steps 300] [--policy fp4] [--ckpt /tmp/fp4_ckpt] [--d-model 512]

`--policy fp4_fused` runs every GeMM through the single-pass Pallas
clamp+quantize+GEMM pipeline (`pallas_fused` backend, DESIGN.md §12) --
interpret-mode simulation on CPU, so expect it slower here; on TPU it is
the one-HBM-pass path. `fp4_fused_obs` adds the quant-health telemetry.

~100M params: d=512, L=8, ff=2048, vocab=32000 (tied). On CPU this runs a
few hundred steps in minutes at seq 256 / batch 8 -- the shape of the real
pretraining loop, scaled down.

Quant-health logging (DESIGN.md §11): pass `--obs-log health.jsonl` to
record per-step FP4 telemetry -- per-layer OCC clamp fraction and residual
mass, quantization scale extrema and underflow counts, quantize/dequantize
SNR, and the DGE forward/backward mismatch -- plus worst-site aggregates
(`agg/min_snr_db`, `agg/max_clamp_frac`, ...). Each training step appends
one JSON object to the log; read it back with `repro.obs.read_jsonl` or
any `jq`-style tool. The flag also arms the activation-collapse sentinel:
if clamp fraction / SNR trends breach the thresholds for `patience`
consecutive steps, the trainer checkpoints and flips to a bf16-policy
step function (events `collapse_trip` / `bf16_fallback` in the history).
Telemetry needs the unrolled execution mode, so `--obs-log` forces
`scan_layers=False` (fine at example scale; see DESIGN.md §11).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_model
from repro.obs import SentinelConfig
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="fp4")
    ap.add_argument("--ckpt", default="/tmp/fp4_ckpt")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--obs-log", default=None, metavar="PATH",
                    help="write per-step quant-health JSONL here and arm "
                         "the collapse sentinel (DESIGN.md §11)")
    args = ap.parse_args()

    obs_on = args.obs_log is not None
    cfg = get_config("llama2-400m").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=8,
        d_ff=args.d_model * 4, vocab_size=32000, tie_embeddings=True,
        loss_chunk=128, remat=False,
        # per-layer telemetry requires the unrolled observability
        # configuration (records inside lax.scan cannot be harvested)
        scan_layers=not obs_on)
    policy = get_policy(args.policy)
    if obs_on:
        policy = policy.replace(obs_metrics=True)
    model = build_model(cfg, policy)

    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, policy={args.policy}"
          f"{' +obs' if obs_on else ''}")

    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(ts_mod.make_train_step(
        model, None, adam_cfg=adam_cfg, total_steps=args.steps,
        peak_lr=3e-4), donate_argnums=0)

    fallback_fn = None
    if obs_on:
        # the sentinel's escape hatch: same weights, quantization disabled
        fb_model = build_model(cfg, policy.fallback())
        fallback_fn = jax.jit(ts_mod.make_train_step(
            fb_model, None, adam_cfg=adam_cfg, total_steps=args.steps,
            peak_lr=3e-4), donate_argnums=0)

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    trainer = Trainer(
        step_fn, state,
        batch_fn=lambda s: {"tokens": jnp.asarray(data.global_batch(s))},
        cfg=TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                          ckpt_every=100, log_every=20,
                          obs_jsonl=args.obs_log,
                          sentinel=SentinelConfig() if obs_on else None),
        fallback_step_fn=fallback_fn)
    history = trainer.run()
    losses = [h["loss"] for h in history if "loss" in h]
    print(f"steps run: {len(losses)}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if trainer.watchdog.flagged:
        print(f"straggler steps flagged: {trainer.watchdog.flagged[:5]}")
    if obs_on:
        summ = trainer.obs_summary()
        for key in ("agg/min_snr_db", "agg/max_clamp_frac",
                    "agg/max_underflow_frac"):
            if key in summ:
                s = summ[key]
                print(f"health {key}: p50={s['p50']:.3g} p95={s['p95']:.3g} "
                      f"last={s['last']:.3g}")
        if trainer.fallback_active:
            print("collapse sentinel tripped -> bf16 fallback engaged")
        print(f"quant-health log: {args.obs_log}")


if __name__ == "__main__":
    main()
