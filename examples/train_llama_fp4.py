"""End-to-end driver: train a ~100M-param LLaMA with the full production
stack -- FP4 policy, mixed-precision Adam, warmup+cosine schedule, atomic
checkpointing with resume (model AND data cursor), NaN guards, straggler
watchdog, async input prefetch.

    PYTHONPATH=src python examples/train_llama_fp4.py \
        [--data corpus/] [--steps 300] [--policy fp4] [--ckpt /tmp/fp4_ckpt]

CLI flags
---------
--steps N           total optimizer steps (default 300).
--policy NAME       quantization preset from `repro.core.policy.PRESETS`
                    (default "fp4"). Highlights: `fp4` = the paper recipe
                    (W4A4 + DGE + OCC); `fp4_fused` runs every GeMM
                    through the single-pass Pallas clamp+quantize+GEMM
                    pipeline (DESIGN.md §12 -- interpret-mode simulation
                    on CPU, the one-HBM-pass path on TPU); `fp4_obs` /
                    `fp4_fused_obs` add quant-health telemetry; `bf16`
                    disables quantization.
--ckpt DIR          checkpoint directory (default /tmp/fp4_ckpt). Restart
                    the same command to resume; with `--data` the input
                    stream position is restored bit-exactly from the
                    checkpoint manifest (DESIGN.md §14).
--d-model D         model width (default 512; ~100M params with the
                    defaults below).
--layers L          transformer depth (default 8).
--seq S             training sequence length (default 256).
--batch B           global batch size in sequences (default 8).
--data PATH         shard-corpus directory or manifest.json
                    (docs/data_format.md). Batches then come from the
                    resumable best-fit packing stream with segment-ID
                    attention masks. Omit for the synthetic fallback
                    stream (no files needed).
--make-data N       with `--data DIR`: if DIR has no manifest yet, first
                    materialize N synthetic documents as shards there
                    (quick way to exercise the on-disk path; real corpora
                    are written with `repro.data.ShardWriter`).
--prefetch K        device prefetch read-ahead depth (default 2); the
                    next batch is packed and staged on-device while the
                    current step runs. `--prefetch 0` disables the
                    background thread (blocking fetch -- the arm
                    `benchmarks/data_bench.py` measures against).
--obs-log PATH      write per-step quant-health JSONL here and arm the
                    collapse sentinel (DESIGN.md §11): per-layer OCC
                    clamp fraction / residual mass, scale extrema and
                    underflow counts, quantize SNR, DGE mismatch, plus
                    worst-site aggregates and input-pipeline health
                    (data/stall_ms, data/queue_depth, data/pack_frac).
                    On sentinel trip the trainer checkpoints and flips to
                    a bf16-policy step function. Telemetry needs the
                    unrolled execution mode, so this forces
                    scan_layers=False (fine at example scale).

~100M params: d=512, L=8, ff=2048, vocab=32000 (tied). On CPU this runs a
few hundred steps in minutes at seq 256 / batch 8 -- the shape of the real
pretraining loop, scaled down.
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.data import (DataConfig, DevicePrefetcher, PackedStream,
                        ShardReader, SyntheticLM, SyntheticStream,
                        write_synthetic_shards)
from repro.models import build_model
from repro.obs import SentinelConfig
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig


def build_loader(args, vocab_size: int):
    """Data path selection: shard corpus (--data) vs synthetic fallback."""
    if args.data:
        manifest = args.data if args.data.endswith(".json") else \
            os.path.join(args.data, "manifest.json")
        if not os.path.exists(manifest) and args.make_data:
            print(f"materializing {args.make_data} synthetic docs "
                  f"into {args.data}")
            write_synthetic_shards(
                args.data, DataConfig(vocab_size, args.seq, args.batch),
                args.make_data)
        reader = ShardReader(manifest)
        stream = PackedStream(reader, seq_len=args.seq,
                              batch_size=args.batch, seed=0)
        src = (f"shards ({reader.total_docs} docs, "
               f"{reader.total_tokens/1e6:.1f}M tokens)")
    else:
        stream = SyntheticStream(
            SyntheticLM(DataConfig(vocab_size, args.seq, args.batch)))
        src = "synthetic"
    if args.prefetch > 0:
        place = lambda arrays: {k: jnp.asarray(v)
                                for k, v in arrays.items()}
        return DevicePrefetcher(stream, place_fn=place,
                                depth=args.prefetch), src + " +prefetch"
    return stream, src


def main():
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="fp4")
    ap.add_argument("--ckpt", default="/tmp/fp4_ckpt")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--data", default=None, metavar="PATH",
                    help="shard corpus dir or manifest.json "
                         "(docs/data_format.md); omit for synthetic data")
    ap.add_argument("--make-data", type=int, default=0, metavar="N",
                    help="generate N synthetic docs into --data if empty")
    ap.add_argument("--prefetch", type=int, default=2, metavar="K",
                    help="async device prefetch depth (0 = blocking fetch)")
    ap.add_argument("--obs-log", default=None, metavar="PATH",
                    help="write per-step quant-health JSONL here and arm "
                         "the collapse sentinel (DESIGN.md §11)")
    args = ap.parse_args()

    obs_on = args.obs_log is not None
    cfg = get_config("llama2-400m").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=8,
        d_ff=args.d_model * 4, vocab_size=32000, tie_embeddings=True,
        loss_chunk=128, remat=False,
        # per-layer telemetry requires the unrolled observability
        # configuration (records inside lax.scan cannot be harvested)
        scan_layers=not obs_on)
    policy = get_policy(args.policy)
    if obs_on:
        policy = policy.replace(obs_metrics=True)
    model = build_model(cfg, policy)

    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    loader, src = build_loader(args, cfg.vocab_size)
    print(f"model: {n_params/1e6:.1f}M params, policy={args.policy}"
          f"{' +obs' if obs_on else ''}, data={src}")

    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(ts_mod.make_train_step(
        model, None, adam_cfg=adam_cfg, total_steps=args.steps,
        peak_lr=3e-4), donate_argnums=0)

    fallback_fn = None
    if obs_on:
        # the sentinel's escape hatch: same weights, quantization disabled
        fb_model = build_model(cfg, policy.fallback())
        fallback_fn = jax.jit(ts_mod.make_train_step(
            fb_model, None, adam_cfg=adam_cfg, total_steps=args.steps,
            peak_lr=3e-4), donate_argnums=0)

    trainer = Trainer(
        step_fn, state, loader=loader,
        cfg=TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                          ckpt_every=100, log_every=20,
                          obs_jsonl=args.obs_log,
                          sentinel=SentinelConfig() if obs_on else None),
        fallback_step_fn=fallback_fn)
    try:
        history = trainer.run()
    finally:
        if hasattr(loader, "stop"):
            loader.stop()
    losses = [h["loss"] for h in history if "loss" in h]
    if losses:
        print(f"steps run: {len(losses)}; "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    else:
        print("steps run: 0 (checkpoint already at --steps; nothing to do)")
    if trainer._last_data_stats:
        d = trainer._last_data_stats
        print(f"input pipeline: stall={d['stall_ms']:.2f}ms/step "
              f"depth={d['queue_depth']:.1f} pack={d['pack_frac']:.3f}")
    if trainer.watchdog.flagged:
        print(f"straggler steps flagged: {trainer.watchdog.flagged[:5]}")
    if obs_on:
        summ = trainer.obs_summary()
        for key in ("agg/min_snr_db", "agg/max_clamp_frac",
                    "agg/max_underflow_frac"):
            if key in summ:
                s = summ[key]
                print(f"health {key}: p50={s['p50']:.3g} p95={s['p95']:.3g} "
                      f"last={s['last']:.3g}")
        if trainer.fallback_active:
            print("collapse sentinel tripped -> bf16 fallback engaged")
        print(f"quant-health log: {args.obs_log}")


if __name__ == "__main__":
    main()
