"""End-to-end driver: train a ~100M-param LLaMA with the full production
stack -- FP4 policy, mixed-precision Adam, warmup+cosine schedule, atomic
checkpointing with resume, NaN guards, straggler watchdog.

    PYTHONPATH=src python examples/train_llama_fp4.py \
        [--steps 300] [--policy fp4] [--ckpt /tmp/fp4_ckpt] [--d-model 512]

~100M params: d=512, L=8, ff=2048, vocab=32000 (tied). On CPU this runs a
few hundred steps in minutes at seq 256 / batch 8 -- the shape of the real
pretraining loop, scaled down.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="fp4")
    ap.add_argument("--ckpt", default="/tmp/fp4_ckpt")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("llama2-400m").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=8,
        d_ff=args.d_model * 4, vocab_size=32000, tie_embeddings=True,
        loss_chunk=128, remat=False, scan_layers=True)
    policy = get_policy(args.policy)
    model = build_model(cfg, policy)

    params, _ = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, policy={args.policy}")

    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(ts_mod.make_train_step(
        model, None, adam_cfg=adam_cfg, total_steps=args.steps,
        peak_lr=3e-4), donate_argnums=0)

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    trainer = Trainer(
        step_fn, state,
        batch_fn=lambda s: {"tokens": jnp.asarray(data.global_batch(s))},
        cfg=TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                          ckpt_every=100, log_every=20))
    history = trainer.run()
    losses = [h["loss"] for h in history if "loss" in h]
    print(f"steps run: {len(losses)}; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if trainer.watchdog.flagged:
        print(f"straggler steps flagged: {trainer.watchdog.flagged[:5]}")


if __name__ == "__main__":
    main()
