"""Paper Fig. 6 ablations, runnable at CPU scale: DGE (k sweep) and OCC
(alpha sweep) on a tiny LLaMA with identical data.

    PYTHONPATH=src python examples/ablation_dge_occ.py [--steps 80]
"""
import argparse

from repro.core.policy import FP4_PAPER, W4A8, W8A4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    from benchmarks.convergence import train_arm, _tail_mean

    print("# DGE k sweep (weight-only W4A8, paper Fig. 6b)")
    for k in [1.0, 3.0, 5.0, 8.0]:
        final = _tail_mean(train_arm(W4A8.replace(dge_k=k), args.steps))
        print(f"k={k:<4} final_loss={final:.4f}")

    print("\n# OCC alpha sweep (activation-only W8A4, paper Fig. 6c)")
    for alpha in [0.999, 0.99, 0.97]:
        final = _tail_mean(train_arm(W8A4.replace(occ_alpha=alpha),
                                     args.steps))
        print(f"alpha={alpha:<6} final_loss={final:.4f}")

    print("\n# Full recipe")
    final = _tail_mean(train_arm(FP4_PAPER, args.steps))
    print(f"W4A4+DGE+OCC final_loss={final:.4f}")


if __name__ == "__main__":
    main()
