"""Quickstart: train a tiny LLaMA in FP4 for 30 steps on CPU and watch the
loss fall; compare against the BF16 baseline on identical data.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import adam as adam_mod


def train(policy_name: str, steps: int = 30):
    cfg = get_config("llama2-400m", smoke=True).replace(
        d_model=128, d_ff=256, vocab_size=512, loss_chunk=64)
    policy = get_policy(policy_name)
    if policy.occ:
        policy = policy.replace(occ_threshold="exact")
    model = build_model(cfg, policy)
    params, _ = model.init(jax.random.PRNGKey(0))
    adam_cfg = adam_mod.AdamConfig(weight_decay=0.01)
    opt = adam_mod.init_state(params, adam_cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 128, 8, seed=1))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        grads, _ = adam_mod.clip_by_global_norm(grads, 1.0)
        params, opt = adam_mod.apply_update(params, grads, opt, 1e-3, adam_cfg)
        return params, opt, loss

    print(f"--- {policy_name} ---")
    for s in range(steps):
        batch = {"tokens": jnp.asarray(data.global_batch(s))}
        params, opt, loss = step(params, opt, batch)
        if s % 5 == 0 or s == steps - 1:
            print(f"step {s:3d}  loss {float(loss):.4f}")
    return float(loss)


if __name__ == "__main__":
    l_bf16 = train("bf16")
    l_fp4 = train("fp4")
    print(f"\nfinal: bf16 {l_bf16:.4f} vs fp4 {l_fp4:.4f} "
          f"(gap {l_fp4 - l_bf16:+.4f}) -- the paper's claim is that this "
          f"gap stays small while GeMMs run 2-4x faster on FP4 hardware.")
