"""Property tests for OCC (core/occ.py): the exact decomposition identity
x == clamp(x) + residual in both threshold modes, and `_strided_sample`
degeneracy guarantees. Hypothesis when installed, the deterministic shim
otherwise (tests/_hypothesis_shim.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:                                        # pragma: no cover
    from _hypothesis_shim import given, settings, st, hnp

from repro.core import occ

_ELEMS = st.floats(min_value=-1e4, max_value=1e4, width=32,
                   allow_nan=False, allow_infinity=False)
_SHAPES = hnp.array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=12)


# hypothesis' @given produces a zero-arg wrapper, so the mode parametrize
# lives in a plain test that drives a given-decorated inner function
@pytest.mark.parametrize("mode", ["exact", "sample"])
def test_identity_property(mode):
    @settings(max_examples=30, deadline=None)
    @given(hnp.arrays(np.float32, _SHAPES, elements=_ELEMS))
    def inner(x_np):
        x = jnp.asarray(x_np)
        x_c, res = occ.clamp_and_residual(x, 0.99, mode=mode)
        # identity: residual is *defined* as x - clamp(x), so the sum
        # reconstructs regardless of threshold quality. Bit-exact when
        # x and x_c share magnitude (Sterbenz); one f32 rounding of the
        # larger operand otherwise -- bound by ulp of the absmax.
        tol = 4.0 * float(np.spacing(np.max(np.abs(x_np)) + 1.0))
        np.testing.assert_allclose(np.asarray(x_c + res), x_np,
                                   rtol=0, atol=tol)
        # clamped tensor bounded by the thresholds actually used
        lo, hi = occ.quantile_thresholds(x, 0.99, mode)
        assert np.all(np.asarray(x_c) >= float(lo) - 1e-6)
        assert np.all(np.asarray(x_c) <= float(hi) + 1e-6)
    inner()


@pytest.mark.parametrize("mode", ["exact", "sample"])
def test_identity_all_equal_tensor(mode):
    """Every quantile of a constant tensor is the constant: clamp is the
    identity and the residual is exactly zero."""
    x = jnp.full((7, 13), 3.25, jnp.float32)
    x_c, res = occ.clamp_and_residual(x, 0.99, mode=mode)
    np.testing.assert_array_equal(np.asarray(x_c), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(res), 0.0)


@pytest.mark.parametrize("mode", ["exact", "sample"])
def test_identity_all_outlier_tensor(mode):
    """Huge-magnitude mixed-sign tensor: identity still exact, and the
    residual carries the clipped outlier mass."""
    rng = np.random.default_rng(0)
    x_np = (rng.choice([-1.0, 1.0], size=(64, 64)) * 1e6).astype(np.float32)
    x = jnp.asarray(x_np)
    x_c, res = occ.clamp_and_residual(x, 0.99, mode=mode)
    np.testing.assert_array_equal(np.asarray(x_c + res), x_np)


@pytest.mark.parametrize("mode", ["exact", "sample"])
def test_identity_one_element(mode):
    x = jnp.asarray([42.0], jnp.float32)
    x_c, res = occ.clamp_and_residual(x, 0.999, mode=mode)
    np.testing.assert_array_equal(np.asarray(x_c + res), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(res), 0.0)  # its own quantile


# ------------------------------------------------------------ strided sample

@pytest.mark.parametrize("shape", [(1,), (2,), (1, 1), (3, 1, 1), (5,),
                                   (1, 7), (2, 3, 5)])
def test_strided_sample_never_empty_tiny(shape):
    x = jnp.ones(shape, jnp.float32)
    out = occ._strided_sample(x, 65536)
    assert out.size > 0
    # tensors already under target pass through whole
    assert out.size == x.size


@pytest.mark.parametrize("target", [1, 2, 64, 1000])
def test_strided_sample_never_empty_large(target):
    x = jnp.arange(4096, dtype=jnp.float32).reshape(64, 64)
    out = occ._strided_sample(x, target)
    assert out.size > 0


def test_strided_sample_is_subset():
    rng = np.random.default_rng(1)
    x_np = rng.standard_normal((128, 96)).astype(np.float32)
    out = np.asarray(occ._strided_sample(jnp.asarray(x_np), 512))
    assert out.size > 0
    assert np.all(np.isin(out, x_np.reshape(-1)))


def test_strided_sample_respects_target_scale():
    """The sample lands within a small factor of the target (strides are
    per-axis so the bound is loose, but it must not blow back up to the
    full tensor)."""
    x = jnp.zeros((512, 512), jnp.float32)
    out = occ._strided_sample(x, 1024)
    assert 0 < out.size <= 8 * 1024


def test_sample_mode_threshold_close_to_exact():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_t(3.0, size=(512, 256)), jnp.float32)
    lo_e, hi_e = occ.quantile_thresholds(x, 0.99, "exact")
    lo_s, hi_s = occ.quantile_thresholds(x, 0.99, "sample")
    # O(1/sqrt(n)) quantile estimate; residual path absorbs the difference
    assert abs(float(hi_s) - float(hi_e)) < 0.5 * abs(float(hi_e)) + 0.1
    assert abs(float(lo_s) - float(lo_e)) < 0.5 * abs(float(lo_e)) + 0.1
