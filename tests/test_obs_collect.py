"""repro.obs collection: collector semantics, jit/grad survival on the
instrumented smoke model, trace-safety suspensions, sinks."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core.policy import get_policy
from repro.models import build_model
from repro.obs.sinks import JsonlWriter, RollingWindow, read_jsonl

CFG = get_config("llama2-400m", smoke=True)   # unrolled, remat off: the
SEQ, BATCH = 32, 2                            # observability configuration
OBS_POLICY = get_policy("fp4_obs")


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(
        rng.integers(0, CFG.vocab_size, (BATCH, SEQ)), jnp.int32)}


# ------------------------------------------------------------ collector unit

def test_collector_scopes_and_aggregate():
    with obs.collect() as col:
        with obs.scope("L0"):
            with obs.site("wq") as rec:
                assert rec is True
                obs.record("clamp_frac", 0.1)
        with obs.scope("L1"):
            with obs.site("wq"):
                obs.record("clamp_frac", 0.3)
                obs.record("snr_db", 12.0)
        out = col.harvest()
    assert float(out["L0/wq/clamp_frac"]) == pytest.approx(0.1)
    assert float(out["L1/wq/clamp_frac"]) == pytest.approx(0.3)
    assert float(out["agg/max_clamp_frac"]) == pytest.approx(0.3)
    assert float(out["agg/min_snr_db"]) == pytest.approx(12.0)
    assert float(out["agg/n_sites"]) == 2.0


def test_no_collector_is_noop():
    assert obs.active() is None
    obs.record("clamp_frac", 1.0)          # must not raise
    obs.record_clamp(jnp.ones(4), jnp.zeros(4))
    with obs.site("x") as rec:
        assert rec is False


def test_collect_disabled_yields_none():
    with obs.collect(enabled=False) as col:
        assert col is None
        assert obs.active() is None


def test_suspended_drops_records():
    with obs.collect() as col:
        obs.record("clamp_frac", 0.5)
        with obs.suspended():
            obs.record("clamp_frac", 0.9)  # dropped
            assert obs.active() is None
        out = col.harvest()
    assert float(out["clamp_frac"]) == pytest.approx(0.5)
    assert float(out["agg/max_clamp_frac"]) == pytest.approx(0.5)


def test_suppress_wraps_fn():
    def body():
        obs.record("mse", 123.0)
    with obs.collect() as col:
        obs.suppress(body)()
        assert "mse" not in col.harvest()


def test_auto_site_numbering():
    with obs.collect() as col:
        with obs.site():
            obs.record("mse", 1.0)
        with obs.site():
            obs.record("mse", 2.0)
        out = col.harvest()
    assert "site0/mse" in out and "site1/mse" in out


# ----------------------------------------------------- jit/grad end-to-end

def test_obs_survives_jit_and_grad():
    model = build_model(CFG, OBS_POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: model.loss(q, b), has_aux=True)(p)
        return loss, metrics, grads

    loss, metrics, grads = step(params, _batch())
    assert "obs" in metrics
    host = {k: float(v) for k, v in jax.device_get(metrics["obs"]).items()}
    # every unrolled layer exposes every GeMM site with the full vocabulary
    for layer in range(CFG.n_layers):
        for gemm in ("wq", "wk", "wv", "wo", "wg", "wu", "wd"):
            assert f"L{layer}/{gemm}/clamp_frac" in host
            assert f"L{layer}/{gemm}/act/snr_db" in host
            assert f"L{layer}/{gemm}/act/underflow_frac" in host
            assert f"L{layer}/{gemm}/weight/dge_mismatch" in host
    for agg in ("agg/min_snr_db", "agg/max_clamp_frac",
                "agg/max_underflow_frac", "agg/max_residual_mass",
                "agg/n_sites"):
        assert agg in host
    assert np.isfinite(host["agg/min_snr_db"])
    assert 0.0 <= host["agg/max_clamp_frac"] <= 1.0
    # health scalars are stop_gradiented: grads stay finite
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in jax.tree.leaves(grads))


def test_obs_off_metrics_unchanged():
    model = build_model(CFG, get_policy("fp4"))
    params, _ = model.init(jax.random.PRNGKey(0))
    _, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, _batch())
    assert "obs" not in metrics


def test_obs_values_match_between_policies():
    """The obs hooks must not perturb the computation: loss identical with
    obs on and off (same params, same batch)."""
    b = _batch(3)
    m_off = build_model(CFG, get_policy("fp4"))
    params, _ = m_off.init(jax.random.PRNGKey(1))
    loss_off, _ = jax.jit(lambda p: m_off.loss(p, b))(params)
    m_on = build_model(CFG, OBS_POLICY)
    loss_on, metrics = jax.jit(lambda p: m_on.loss(p, b))(params)
    np.testing.assert_allclose(float(loss_off), float(loss_on), rtol=1e-6)
    assert "obs" in metrics


@pytest.mark.parametrize("scan_layers,remat", [(True, False), (False, True),
                                               (True, True)])
def test_inner_trace_configs_safe(scan_layers, remat):
    """scan/remat introduce inner traces; collection suspends there rather
    than leaking tracers. Loss must still compute under jit."""
    cfg = CFG.replace(scan_layers=scan_layers, remat=remat)
    model = build_model(cfg, OBS_POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, _batch())
    assert np.isfinite(float(loss))
    if "obs" in metrics:
        # whatever was recorded outside the inner traces must be finite
        for v in jax.device_get(metrics["obs"]).values():
            assert np.isfinite(float(v))


# ------------------------------------------------------------------- decode

def test_serve_decode_emits_health(tmp_path):
    from repro.serve.engine import greedy_generate
    model = build_model(CFG, OBS_POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    log = tmp_path / "decode_health.jsonl"
    with JsonlWriter(str(log)) as w:
        out = greedy_generate(model, params, _batch(), steps=4,
                              max_len=SEQ + 8, obs_writer=w)
    assert out.shape == (BATCH, 4)
    recs = read_jsonl(str(log))
    assert len(recs) == 3                      # steps - 1 decode steps
    assert {r["decode_step"] for r in recs} == {0, 1, 2}
    assert "agg/min_snr_db" in recs[0]
    assert any(k.endswith("/clamp_frac") for k in recs[0])


# -------------------------------------------------------------------- sinks

def test_jsonl_writer_roundtrip(tmp_path):
    path = tmp_path / "m.jsonl"
    w = JsonlWriter(str(path))
    w.write({"step": 0, "loss": 1.5})
    w.write({"step": 1, "loss": 1.25, "agg/min_snr_db": 17.0})
    w.close()
    recs = read_jsonl(str(path))
    assert [r["step"] for r in recs] == [0, 1]
    assert recs[1]["agg/min_snr_db"] == 17.0
    # append mode: a reopened writer extends the same file
    with JsonlWriter(str(path)) as w2:
        w2.write({"step": 2, "loss": 1.0})
    assert len(read_jsonl(str(path))) == 3
    # each line is standalone JSON
    lines = path.read_text().strip().split("\n")
    assert all(isinstance(json.loads(l), dict) for l in lines)


def test_rolling_window_summary():
    win = RollingWindow(size=4)
    for i in range(10):
        win.push({"snr": float(i), "note": "text-ignored"})
    assert len(win) == 4                       # only the last 4 kept
    s = win.summary()
    assert s["snr"]["min"] == 6.0 and s["snr"]["max"] == 9.0
    assert s["snr"]["last"] == 9.0
    assert 6.0 <= s["snr"]["p50"] <= 9.0
    assert "note" not in s                     # non-numeric dropped


def test_rolling_window_empty():
    assert RollingWindow(8).summary() == {}
