"""Decode-equivalence battery for the continuous-batching serve engine.

Metamorphic properties (DESIGN.md §13): how a request is *scheduled* must
never change what it *decodes*. Greedy decoding is compared token-for-token
across
  * alone vs packed into a continuous batch with other live requests,
  * paged KV cache vs dense ring cache,
  * engine vs the plain `greedy_generate` host loop (left-padded
    shape-stable prefill vs unpadded prefill),
  * staggered admission (requests arriving while others are mid-decode).

f32 compute keeps the comparisons exact; an FP4-policy arm checks the
quantized path too (OCC off there: its per-tensor activation quantile is
the one knob that legitimately couples slots, DESIGN.md §13).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import BF16, get_policy
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.engine import greedy_generate

POLICY = BF16.replace(compute="float32")
GEN = 6


@pytest.fixture(scope="module")
def mp():
    cfg = get_config("llama2-400m", smoke=True).replace(
        cache_dtype="float32", remat=False)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(cfg_vocab=256, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg_vocab,
                         size=int(rng.integers(3, 14))).tolist()
            for _ in range(n)]


def _engine(model, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 48)
    kw.setdefault("prefill_len", 16)
    kw.setdefault("page_size", 4)
    return ServeEngine(model, params, **kw)


def _drain(eng, prompts, gen=GEN):
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    eng.check_invariants()
    assert all(res[r]["state"] == "done" for r in rids)
    return [res[r]["tokens"] for r in rids]


# ----------------------------------------------------------- batch invariance

def test_alone_vs_packed_batch_invariance(mp):
    model, params = mp
    prompts = _prompts(model.cfg.vocab_size)
    packed = _drain(_engine(model, params), prompts)
    for i, p in enumerate(prompts):
        alone = _drain(_engine(model, params), [p])
        assert alone[0] == packed[i], \
            f"request {i}: alone {alone[0]} != packed {packed[i]}"


def test_staggered_admission_invariance(mp):
    """Requests arriving mid-flight (continuous batching) decode the same
    tokens as a cold fully-packed batch."""
    model, params = mp
    prompts = _prompts(model.cfg.vocab_size)
    packed = _drain(_engine(model, params), prompts)

    eng = _engine(model, params, n_slots=2)   # forces queueing + reuse
    rids = [eng.submit(p, GEN) for p in prompts[:2]]
    eng.step(); eng.step()                    # first two mid-decode
    rids += [eng.submit(p, GEN) for p in prompts[2:]]
    res = eng.run()
    eng.check_invariants()
    got = [res[r]["tokens"] for r in rids]
    assert got == packed


# ------------------------------------------------------------ paged vs dense

def test_paged_vs_dense_equivalence(mp):
    model, params = mp
    prompts = _prompts(model.cfg.vocab_size)
    paged = _drain(_engine(model, params, paged=True), prompts)
    dense = _drain(_engine(model, params, paged=False), prompts)
    assert paged == dense


@pytest.mark.parametrize("page_size", [1, 4, 16])
def test_page_size_invariance(mp, page_size):
    model, params = mp
    prompts = _prompts(model.cfg.vocab_size, n=3, seed=3)
    ref = _drain(_engine(model, params, paged=False), prompts)
    got = _drain(_engine(model, params, page_size=page_size), prompts)
    assert got == ref


# -------------------------------------------------- engine vs host-loop ref

def test_engine_matches_greedy_generate(mp):
    """Left-padded shape-stable engine prefill == unpadded host loop."""
    model, params = mp
    prompts = _prompts(model.cfg.vocab_size, seed=7)
    got = _drain(_engine(model, params), prompts)
    for i, p in enumerate(prompts):
        ref = greedy_generate(model, params,
                              {"tokens": jnp.asarray([p], jnp.int32)},
                              steps=GEN, max_len=48)
        assert got[i] == np.asarray(ref)[0].tolist(), f"request {i}"


# ------------------------------------------------------------------ fp4 arm

@pytest.fixture(scope="module")
def mp_fp4():
    cfg = get_config("llama2-400m", smoke=True).replace(remat=False)
    # OCC off: its activation clamp threshold is a per-tensor quantile,
    # so it (by design) couples the slots of a batch; every other part
    # of the FP4 path is row-wise and must be batch-invariant.
    model = build_model(cfg, get_policy("fp4").replace(occ=False))
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


def test_fp4_alone_vs_packed(mp_fp4):
    model, params = mp_fp4
    prompts = _prompts(model.cfg.vocab_size, n=3, seed=11)
    packed = _drain(_engine(model, params), prompts)
    for i, p in enumerate(prompts):
        alone = _drain(_engine(model, params), [p])
        assert alone[0] == packed[i], f"fp4 request {i}"


def test_fp4_ragged_paged_vs_dense(mp_fp4):
    """Row-wise FP4 path: ragged packing (idle lanes, staggered finishes)
    must still be storage-invariant."""
    model, params = mp_fp4
    prompts = _prompts(model.cfg.vocab_size, n=3, seed=17)
    paged = _drain(_engine(model, params, paged=True), prompts)
    dense = _drain(_engine(model, params, paged=False), prompts)
    assert paged == dense


def test_fp4_paged_vs_dense_full_recipe():
    """Full recipe (OCC on, fp8 cache) under uniform lane occupancy: equal
    prompt lengths and budgets, so every slot is live from the first to
    the last step and paged vs dense storage must agree exactly.

    (Under *ragged* occupancy the full recipe is NOT storage-invariant:
    OCC's per-tensor activation quantile sees the garbage in idle slot
    lanes, which legitimately differs between paged and dense caches --
    DESIGN.md §13. Serving deployments that need strict batch invariance
    run OCC off, as `mp_fp4` does.)"""
    cfg = get_config("llama2-400m", smoke=True).replace(
        cache_dtype="float8_e4m3fn", remat=False)
    model = build_model(cfg, get_policy("fp4").replace(
        occ_threshold="exact"))
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, cfg.vocab_size, size=8).tolist()
               for _ in range(4)]
    paged = _drain(_engine(model, params, paged=True), prompts)
    dense = _drain(_engine(model, params, paged=False), prompts)
    assert paged == dense
