"""Vector-wise absmax quantization properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # property tests prefer real hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # bare env: deterministic fallback engine
    from _hypothesis_shim import given, hnp, settings, st

from repro.core import formats, quantize

FMT = formats.E2M1


def _finite_arrays(min_side=1, max_side=16):
    return hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=2, max_dims=2, min_side=min_side, max_side=max_side),
        elements=st.floats(-1e4, 1e4, width=32, allow_nan=False),
    )


@settings(max_examples=50, deadline=None)
@given(_finite_arrays())
def test_quantized_values_on_grid(x):
    q, s = quantize.quantize(jnp.asarray(x), axis=-1)
    grid = set(FMT.values.tolist())
    assert all(float(v) in grid for v in np.asarray(q).reshape(-1))


@settings(max_examples=50, deadline=None)
@given(_finite_arrays())
def test_scale_maps_absmax_to_format_max(x):
    q, s = quantize.quantize(jnp.asarray(x), axis=-1)
    scaled_max = np.max(np.abs(x.astype(np.float64) * np.asarray(s, np.float64)),
                        axis=-1)
    # rows with absmax <= 1e-30 quantize to zero (f32 scale would overflow)
    rows_nonzero = np.max(np.abs(x), axis=-1).reshape(-1) > 1e-30
    np.testing.assert_allclose(scaled_max.reshape(-1)[rows_nonzero], 6.0, rtol=1e-4)


@settings(max_examples=50, deadline=None)
@given(_finite_arrays())
def test_dequant_error_bounded_by_half_interval(x):
    # absmax scaling => scaled values in [-6, 6]; max rounding error is half
    # the widest interval (1.0) in scaled space => error <= 1.0/scale.
    xj = jnp.asarray(x)
    q, s = quantize.quantize(xj, axis=-1)
    deq = np.asarray(quantize.dequantize(q, s))
    err = np.abs(deq - x)
    bound = (1.0 + 1e-5) / np.asarray(s)
    assert np.all(err <= bound + 1e-30)


def test_token_vs_channel_axis_semantics():
    x = jnp.asarray([[1.0, 2.0], [100.0, 200.0]], jnp.float32)
    _, s_tok = quantize.quantize(x, axis=-1)   # per-row
    assert s_tok.shape == (2, 1)
    _, s_ch = quantize.quantize(x, axis=0)     # per-column
    assert s_ch.shape == (1, 2)
    _, s_t = quantize.quantize(x, axis=None)   # tensor-wise
    assert np.asarray(s_t).shape == ()


def test_zero_tensor_safe():
    q, s = quantize.quantize(jnp.zeros((4, 4)), axis=-1)
    assert np.all(np.isfinite(np.asarray(s)))
    np.testing.assert_array_equal(np.asarray(q), 0.0)


def test_fake_quant_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    y = quantize.fake_quant(x, axis=-1)
    z = quantize.fake_quant(y, axis=-1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), rtol=1e-6)


def test_fp8_roundtrip_reasonable():
    x = jax.random.normal(jax.random.PRNGKey(1), (1024,)) * 10
    x8, s = quantize.quantize_fp8(x)
    assert x8.dtype == jnp.float8_e4m3fn
    back = quantize.dequantize_fp8(x8, s)
    rel = np.abs(np.asarray(back) - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-6)
    assert np.median(rel) < 0.05  # e4m3 has ~2 decimal digits
