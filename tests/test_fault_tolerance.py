"""Fault tolerance: resume-exact training, failure recovery, NaN guards,
straggler watchdog, elastic resharding across mesh shapes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import BF16
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models import build_model
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig

CFG = get_config("llama2-400m", smoke=True).replace(loss_chunk=32)
SEQ, BATCH = 32, 4


def _setup(total_steps=10, ckpt_dir=None, fail_injector=None,
           schedule_steps=10):
    """schedule_steps is the LR schedule horizon and must stay FIXED across
    interrupted/resumed runs (resuming with a different schedule is a
    config change, not a resume)."""
    model = build_model(CFG, BF16)
    params, _ = model.init(jax.random.PRNGKey(0))
    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(ts_mod.make_train_step(model, None, adam_cfg=adam_cfg,
                                             total_steps=schedule_steps))
    data = SyntheticLM(DataConfig(CFG.vocab_size, SEQ, BATCH))
    batch_fn = lambda s: {"tokens": jnp.asarray(data.global_batch(s))}
    return Trainer(step_fn, state, batch_fn,
                   TrainerConfig(total_steps=total_steps, ckpt_dir=ckpt_dir,
                                 ckpt_every=3, max_retries=3),
                   fail_injector=fail_injector)


def test_loss_decreases():
    t = _setup(total_steps=12)
    hist = t.run(resume=False)
    losses = [h["loss"] for h in hist if "loss" in h]
    assert len(losses) == 12
    assert losses[-1] < losses[0]


def test_resume_is_exact(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # one uninterrupted 10-step run
    t_full = _setup(total_steps=10, ckpt_dir=d1)
    hist_full = t_full.run(resume=False)
    # interrupted at step 6 (ckpt_every=3 -> ckpt at 6), then resumed
    t_a = _setup(total_steps=6, ckpt_dir=d2)
    t_a.run(resume=False)
    t_b = _setup(total_steps=10, ckpt_dir=d2)
    hist_b = t_b.run(resume=True)   # resumes from step 6
    full = {h["step"]: h["loss"] for h in hist_full if "loss" in h}
    resumed = {h["step"]: h["loss"] for h in hist_b if "loss" in h}
    for s, l in resumed.items():
        assert s >= 6
        np.testing.assert_allclose(l, full[s], rtol=1e-5,
                                   err_msg=f"step {s} diverges after resume")


def test_failure_recovery(tmp_path):
    """A step that raises is retried from the last good checkpoint."""
    boom = {"armed": True}

    def injector(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    t = _setup(total_steps=8, ckpt_dir=str(tmp_path),
               fail_injector=injector)
    hist = t.run(resume=False)
    events = [h for h in hist if h.get("event") == "restored"]
    assert len(events) == 1
    losses = [h for h in hist if "loss" in h]
    assert losses[-1]["step"] == 7  # completed despite the failure


def test_nan_guard_skips_and_aborts():
    t = _setup(total_steps=6)
    calls = {"n": 0}
    orig = t.step_fn

    def nan_step(state, batch):
        calls["n"] += 1
        new_state, metrics = orig(state, batch)
        metrics = dict(metrics, loss=jnp.float32(jnp.nan))
        return new_state, metrics

    t.step_fn = nan_step
    t.cfg.max_nan_skips = 3
    with pytest.raises(FloatingPointError, match="non-finite"):
        t.run(resume=False)
    skips = [h for h in t.history if h.get("event") == "nan_skip"]
    assert len(skips) == 4  # 3 allowed + the aborting one


def test_straggler_watchdog():
    from repro.train.trainer import StragglerWatchdog
    w = StragglerWatchdog(TrainerConfig(total_steps=1, straggler_k=3.0))
    for _ in range(10):
        assert not w.observe(0, 1.0)
    assert w.observe(11, 10.0)  # 10x slower than EWMA -> flagged
    assert w.flagged


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint under one mesh, restore under another: params identical."""
    from repro.launch.mesh import make_mesh
    from repro.train import checkpoint as ckpt_mod
    from repro.train import elastic

    n = jax.device_count()
    if n < 4:
        pytest.skip("needs >=4 devices (run under fake-device env)")
    model = build_model(CFG, BF16)
    params, axes = model.init(jax.random.PRNGKey(0))
    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    ckpt_mod.save(str(tmp_path), 0, state)
    mesh2 = make_mesh((2, 2), ("data", "model"))
    restored, _ = elastic.elastic_restore(str(tmp_path), state, axes, mesh2)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
