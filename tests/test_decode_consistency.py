"""Prefill+decode must reproduce the parallel forward pass (cache paths are
numerically equivalent to training paths up to cache-dtype rounding).

This is the strongest correctness check for the serving stack: ring buffers,
position masking, SSM/RWKV state carries, MLA latent caches, shared-block
reuse -- any bug shows up as logit divergence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import BF16
from repro.launch.inputs import make_batch
from repro.models import build_model

# BF16 policy isolates cache correctness from FP4 quantization noise
# (fp4 paths are covered by smoke tests; quantization of a slightly
# different numerical path would mask real cache bugs here).
POLICY = BF16.replace(compute="float32")

ARCHS = ["llama2-400m", "gemma2-9b", "gemma3-27b", "minicpm3-4b",
         "qwen3-moe-30b-a3b", "zamba2-7b", "rwkv6-1.6b", "qwen1.5-32b"]


def _parallel_logits(model, params, tokens):
    """All-position logits from the training path."""
    x = model._embed_in(params, {"tokens": tokens})
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _ = model.backbone(params, x, positions)
    logits = jnp.matmul(x, model._head_w(params),
                        preferred_element_type=jnp.float32)
    if model.cfg.final_softcap:
        logits = model.cfg.final_softcap * jnp.tanh(
            logits / model.cfg.final_softcap)
    return logits


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_parallel(arch):
    # capacity_factor high enough to be dropless: the parallel path drops
    # over-capacity tokens (Switch semantics) while single-token decode never
    # does -- dropping is correct but would mask cache bugs here.
    cfg = get_config(arch, smoke=True).replace(cache_dtype="float32",
                                               remat=False,
                                               capacity_factor=8.0)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1,
                                cfg.vocab_size)

    ref = np.asarray(_parallel_logits(model, params, tokens), np.float32)

    cache = model.init_cache(B, S + 4)
    step = jax.jit(model.decode_step)
    got = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        got.append(np.asarray(logits, np.float32))
    got = np.stack(got, axis=1)                       # (B,S,V)

    scale = np.maximum(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(got / scale, ref / scale, atol=2e-3,
                               err_msg=f"{arch}: decode != parallel")


@pytest.mark.parametrize("arch", ["llama2-400m", "zamba2-7b", "rwkv6-1.6b",
                                  "minicpm3-4b"])
def test_prefill_then_decode_matches_parallel(arch):
    cfg = get_config(arch, smoke=True).replace(cache_dtype="float32",
                                               remat=False)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S, S0 = 2, 24, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1,
                                cfg.vocab_size)
    ref = np.asarray(_parallel_logits(model, params, tokens), np.float32)

    cache = model.init_cache(B, S + 4)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": tokens[:, :S0]}, cache)
    scale = np.maximum(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(np.asarray(logits, np.float32) / scale,
                               ref[:, S0 - 1] / scale, atol=2e-3,
                               err_msg=f"{arch}: prefill logits diverge")
    step = jax.jit(model.decode_step)
    for t in range(S0, S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits, np.float32) / scale,
                                   ref[:, t] / scale, atol=2e-3,
                                   err_msg=f"{arch}: decode@{t} diverges")


@pytest.mark.parametrize("arch", ["llama2-400m", "gemma2-9b"])
def test_ragged_prefill_then_decode_matches_parallel(arch):
    """Ragged-length prompts, left-padded to one shape-stable prefill batch
    (pad positions < 0 are rope'd harmlessly and masked out of attention),
    then per-slot vector-position decode -- the serve scheduler's real
    input shapes. Teacher-forced continuation logits must match each
    request's unpadded parallel forward pass."""
    cfg = get_config(arch, smoke=True).replace(cache_dtype="float32",
                                               remat=False)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S, T = 3, 16, 6
    lens = [16, 9, 5]
    rows = [jax.random.randint(jax.random.PRNGKey(10 + b), (1, L + T), 1,
                               cfg.vocab_size) for b, L in enumerate(lens)]
    refs = [np.asarray(_parallel_logits(model, params, r), np.float32)
            for r in rows]
    scale = max(np.abs(r).max() for r in refs)

    toks = np.zeros((B, S), np.int32)
    positions = np.zeros((B, S), np.int32)
    for b, L in enumerate(lens):
        toks[b, S - L:] = np.asarray(rows[b])[0, :L]
        positions[b] = np.arange(S) - (S - L)
    cache = model.init_cache(B, S + T + 4)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks),
                 "positions": jnp.asarray(positions)}, cache)
    for b, L in enumerate(lens):
        np.testing.assert_allclose(
            np.asarray(logits, np.float32)[b] / scale,
            refs[b][0, L - 1] / scale, atol=2e-3,
            err_msg=f"{arch}: ragged prefill logits diverge (slot {b})")

    step = jax.jit(model.decode_step)
    for t in range(T - 1):
        feed = jnp.asarray([[int(np.asarray(rows[b])[0, lens[b] + t])]
                            for b in range(B)], jnp.int32)
        posv = jnp.asarray([lens[b] + t for b in range(B)], jnp.int32)
        logits, cache = step(params, cache, feed, posv)
        for b, L in enumerate(lens):
            np.testing.assert_allclose(
                np.asarray(logits, np.float32)[b] / scale,
                refs[b][0, L + t] / scale, atol=2e-3,
                err_msg=f"{arch}: ragged decode@{t} diverges (slot {b})")


def test_whisper_prefill_decode_consistency():
    cfg = get_config("whisper-medium", smoke=True).replace(
        cache_dtype="float32", remat=False)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, 2 * S, B)  # -> enc S frames, dec S tokens

    memory = model.encode(params, batch["enc_embeds"])
    x = model.decode_train(params, batch["tokens"], memory)
    head = params["embed"].T.astype(x.dtype)
    ref = np.asarray(jnp.matmul(x, head, preferred_element_type=jnp.float32),
                     np.float32)

    cache = model.init_cache(B, S + 4, memory_len=S)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    scale = np.maximum(np.abs(ref).max(), 1.0)
    np.testing.assert_allclose(np.asarray(logits, np.float32) / scale,
                               ref[:, -1] / scale, atol=2e-3)
