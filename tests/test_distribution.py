"""Distribution tests that need multiple devices: run the fake-device
harness as a subprocess (jax locks device count at first init, so the main
test process -- which other tests share -- stays at 1 device)."""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "_fake_device_harness.py")


@pytest.mark.slow
def test_fake_device_harness():
    proc = subprocess.run([sys.executable, HARNESS], capture_output=True,
                          text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"harness failed:\nstdout:\n{proc.stdout[-3000:]}\n" \
        f"stderr:\n{proc.stderr[-3000:]}"
    assert "ALL OK" in proc.stdout


def test_logical_to_spec_rules():
    """Pure-logic sharding rule checks (no devices needed)."""
    import numpy as np
    from repro.dist.sharding import logical_to_spec

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    m = FakeMesh()
    # TP on divisible dims
    assert logical_to_spec(("embed", "mlp"), (512, 2048), m) == \
        __import__("jax").sharding.PartitionSpec(None, "model")
    # kv_heads=4 < model=16 -> replicated
    assert logical_to_spec(("embed", "kv_heads"), (512, 4), m)[1] is None
    # batch -> (pod, data) when divisible by 32
    spec = logical_to_spec(("batch", None), (64, 128), m)
    assert spec[0] == ("pod", "data")
    # batch=1 -> replicated
    spec = logical_to_spec(("batch", None), (1, 128), m)
    assert spec[0] is None
    # one mesh axis never assigned twice
    spec = logical_to_spec(("heads", "mlp"), (32, 2048), m)
    assert list(spec).count("model") == 1
