"""Fast, device-free unit tests for the repro.dist sharding rules:
param_specs/param_shardings, cache_specs/cache_shardings, and
make_act_constraint -- divisibility edge cases, replication fallbacks,
and the one-mesh-axis-never-assigned-twice invariant, beyond the
logical_to_spec contract checks in test_distribution.py."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shard_rules


class FakeMesh:
    """Pure-logic mesh stand-in (no devices needed)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values()))) if axes else 1


MESH3 = FakeMesh(pod=2, data=4, model=4)
MESH2 = FakeMesh(data=8, model=4)


def _flat_axes(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


# ---------------------------------------------------------------- rules

def test_tp_priority_mlp_over_embed_both_directions():
    # column-parallel up-projection and row-parallel down-projection both
    # shard the *mlp* dim, never embed -- one collective per MLP pair
    assert shard_rules.logical_to_spec(("embed", "mlp"), (64, 256), MESH3) \
        == P(None, "model")
    assert shard_rules.logical_to_spec(("mlp", "embed"), (256, 64), MESH3) \
        == P("model", None)


def test_tp_falls_back_down_priority_on_divisibility():
    # heads=6 not divisible by model=4 -> embed (divisible) takes 'model'
    spec = shard_rules.logical_to_spec(("embed", "heads"), (64, 6), MESH3)
    assert spec == P("model", None)
    # nothing divisible -> fully replicated
    spec = shard_rules.logical_to_spec(("embed", "heads"), (6, 6), MESH3)
    assert spec == P(None, None)


def test_batch_requires_full_dp_divisibility():
    # dp world = pod*data = 8; batch=12 is divisible by 4 but not 8 ->
    # replicate (no partial assignment of just one DP axis)
    spec = shard_rules.logical_to_spec(("batch", None), (12, 16), MESH3)
    assert spec[0] is None
    spec = shard_rules.logical_to_spec(("batch", None), (16, 16), MESH3)
    assert spec[0] == ("pod", "data")


def test_batch_single_dp_axis_mesh():
    # no 'pod' axis -> plain 'data' entry, not a 1-tuple
    spec = shard_rules.logical_to_spec(("batch", None), (16, 16), MESH2)
    assert spec[0] == "data"


def test_seq_takes_model_only_when_free():
    spec = shard_rules.logical_to_spec(("batch", "seq", None),
                                       (16, 128, 64), MESH3)
    assert spec == P(("pod", "data"), "model", None)
    # decode step: seq=1 not divisible -> replicated
    spec = shard_rules.logical_to_spec(("batch", "seq", None),
                                       (16, 1, 64), MESH3)
    assert spec[1] is None
    # a TP name already claimed 'model' -> seq must not reuse it
    spec = shard_rules.logical_to_spec(("seq", "mlp"), (128, 256), MESH3)
    assert list(spec).count("model") == 1


def test_no_mesh_axis_assigned_twice_exhaustive():
    names = ["mlp", "heads", "kv_heads", "vocab", "embed", "embed2",
             "expert", "batch", "seq", "layer", None]
    dims = [1, 4, 6, 16, 64]
    for la in itertools.product(names, repeat=2):
        for shape in itertools.product(dims, repeat=2):
            spec = shard_rules.logical_to_spec(la, shape, MESH3)
            flat = _flat_axes(spec)
            assert len(flat) == len(set(flat)), (la, shape, spec)
            # every assignment must divide its dim
            for d, e in zip(shape, spec):
                if e is None:
                    continue
                axes = e if isinstance(e, tuple) else (e,)
                world = int(np.prod([MESH3.shape[a] for a in axes]))
                assert d % world == 0, (la, shape, spec)


def test_short_logical_axes_pad_with_replication():
    # axes tuple shorter than the array rank (stacked scan params append
    # a leading 'layer'): missing entries replicate
    spec = shard_rules.logical_to_spec(("layer",), (8, 64, 256), MESH3)
    assert spec == P(None, None, None)


# --------------------------------------------------------- param trees

def test_param_specs_nested_tree():
    params = {
        "embed": jax.ShapeDtypeStruct((128, 64), jnp.float32),
        "stack": [{
            "wi": jax.ShapeDtypeStruct((4, 64, 256), jnp.float32),
            "wo": jax.ShapeDtypeStruct((4, 256, 64), jnp.float32),
        }],
        "ln_f": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "stack": [{
            "wi": ("layer", "embed", "mlp"),
            "wo": ("layer", "mlp", "embed"),
        }],
        "ln_f": (None,),
    }
    specs = shard_rules.param_specs(axes, params, MESH3)
    assert specs["embed"] == P("model", None)           # vocab-parallel
    assert specs["stack"][0]["wi"] == P(None, None, "model")
    assert specs["stack"][0]["wo"] == P(None, "model", None)
    assert specs["ln_f"] == P(None)


def test_param_shardings_real_mesh_roundtrip():
    # NamedSharding construction needs a real mesh; 1 device => axis
    # sizes 1 => everything replicates, but tree plumbing is exercised
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    shard = shard_rules.param_shardings({"w": ("embed", "mlp")}, params,
                                        mesh)
    assert isinstance(shard["w"], NamedSharding)
    assert shard["w"].spec == P(None, None)


# --------------------------------------------------------------- caches

def _cache_leaf(*shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_cache_specs_unstacked():
    cache = {"layers": [{
        "k": _cache_leaf(16, 128, 4, 32),
        "v": _cache_leaf(16, 128, 4, 32),
        "kv_pos": _cache_leaf(16, 128, dtype=jnp.int32),
    }]}
    specs = shard_rules.cache_specs(cache, MESH3)
    leaf = specs["layers"][0]
    assert leaf["k"] == P(("pod", "data"), "model", None, None)
    assert leaf["kv_pos"] == P(("pod", "data"), "model")


def test_cache_specs_stacked_offset():
    # scan-over-layers cache: leading layer-group dim must replicate and
    # batch/seq rules shift right by one
    cache = {
        "stack": [{"k": _cache_leaf(6, 16, 128, 4, 32)}],
        "rest": [{"k": _cache_leaf(16, 128, 4, 32)}],
    }
    specs = shard_rules.cache_specs(cache, MESH3)
    assert specs["stack"][0]["k"] == P(None, ("pod", "data"), "model",
                                       None, None)
    assert specs["rest"][0]["k"] == P(("pod", "data"), "model", None, None)


def test_cache_specs_replication_fallbacks():
    # ssm conv buffer: seq-like dim 3 is not divisible -> replicated;
    # odd batch -> replicated
    cache = {"layers": [{
        "conv_x": _cache_leaf(16, 3, 64, dtype=jnp.float32),
        "state": _cache_leaf(5, 8, 64, dtype=jnp.float32),
    }]}
    specs = shard_rules.cache_specs(cache, MESH3)
    assert specs["layers"][0]["conv_x"] == P(("pod", "data"), None, None)
    assert specs["layers"][0]["state"] == P(None, "model", None)


def test_cache_shardings_real_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    cache = {"layers": [{"k": _cache_leaf(4, 8, 2, 4)}]}
    shard = shard_rules.cache_shardings(cache, mesh)
    assert isinstance(shard["layers"][0]["k"], NamedSharding)


# ------------------------------------------------------ act constraints

def test_act_constraint_identity_on_single_device_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    f = shard_rules.make_act_constraint(mesh)
    x = jnp.ones((4, 8, 16))
    assert f(x) is x


def test_act_constraint_passes_low_rank_through():
    f = shard_rules.make_act_constraint(FakeMesh(data=4, model=2))
    s = jnp.float32(1.0)
    assert f(s) is s  # scalars (aux losses) untouched, no spec built
