"""DGE estimator: derivative formula, clipping, custom_vjp wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # property tests prefer real hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback engine
    from _hypothesis_shim import given, settings, st

from repro.core import dge, formats, quantize


def test_derivative_matches_eq8_first_interval():
    # First positive interval [0, 0.5]: delta=0.5, f'(x) = (1/k)|4x-1|^(1/k-1)
    k = 5.0
    xs = jnp.asarray([0.05, 0.1, 0.2, 0.3, 0.4, 0.45])
    got = dge.dge_derivative(xs, k=k, clip=1e9)
    t = xs / 0.5
    want = (1.0 / k) * jnp.abs(2 * t - 1) ** (1.0 / k - 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_derivative_clipped_at_midpoint():
    # At interval midpoints the raw derivative diverges; must equal clip.
    mids = jnp.asarray([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0])
    got = dge.dge_derivative(mids, k=5.0, clip=3.0)
    np.testing.assert_allclose(np.asarray(got), 3.0, rtol=1e-4)


def test_derivative_zero_outside_range():
    xs = jnp.asarray([-7.0, 6.5, 100.0])
    np.testing.assert_array_equal(np.asarray(dge.dge_derivative(xs)), 0.0)


def test_derivative_finite_everywhere():
    xs = jnp.linspace(-6.5, 6.5, 10001)
    d = np.asarray(dge.dge_derivative(xs))
    assert np.all(np.isfinite(d))
    assert np.all(d <= 3.0 + 1e-6) and np.all(d >= 0.0)


def test_derivative_symmetric_negative_intervals():
    # E2M1 grid is symmetric; derivative at x and the mirrored position of
    # the mirrored interval should agree.
    xs = jnp.asarray([0.1, 0.6, 1.1, 2.2, 3.3, 4.5])
    d_pos = np.asarray(dge.dge_derivative(xs))
    d_neg = np.asarray(dge.dge_derivative(-xs))
    np.testing.assert_allclose(d_pos, d_neg, rtol=1e-5)


def test_dge_forward_is_hard_quantization():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 4
    np.testing.assert_array_equal(np.asarray(dge.dge_quantize(x)),
                                  np.asarray(quantize.lut_round(x)))


def test_dge_gradient_is_weighted():
    x = jnp.asarray([0.1, 0.4, 1.2, 3.3])
    g = jax.grad(lambda v: jnp.sum(dge.dge_quantize(v)))(x)
    want = dge.dge_derivative(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-5)


def test_ste_gradient_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(1), (32,)) * 4
    g = jax.grad(lambda v: jnp.sum(dge.ste_quantize(v)))(x)
    np.testing.assert_array_equal(np.asarray(g), 1.0)


@settings(max_examples=30, deadline=None)
@given(st.floats(1.5, 10.0), st.floats(1.5, 10.0))
def test_larger_k_sharper_transition(k_small, k_big):
    # Larger k => derivative smaller far from midpoint (flatter plateaus).
    if k_small > k_big:
        k_small, k_big = k_big, k_small
    if abs(k_small - k_big) < 0.2:
        return
    x = jnp.asarray([0.05])  # near interval edge, far from midpoint
    d_small = float(dge.dge_derivative(x, k=k_small, clip=1e9)[0])
    d_big = float(dge.dge_derivative(x, k=k_big, clip=1e9)[0])
    assert d_big <= d_small + 1e-6
