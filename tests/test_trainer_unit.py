"""Trainer unit tests: StragglerWatchdog EWMA semantics, the
on_straggler="checkpoint" action, _try_resume round-trip, and the
batched-host-transfer contract (ONE jax.device_get per step; grad_norm
fetched only on logged steps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt_mod
from repro.train import trainer as trainer_mod
from repro.train.trainer import (StragglerWatchdog, Trainer, TrainerConfig)


def _fake_step(state, batch):
    new = dict(state, step=state["step"] + 1)
    return new, {"loss": jnp.float32(1.0), "grad_norm": jnp.float32(0.5)}


def _state():
    return {"params": {"w": jnp.arange(4, dtype=jnp.float32)},
            "step": jnp.zeros((), jnp.int32)}


def _trainer(cfg, **kw):
    return Trainer(_fake_step, _state(), batch_fn=lambda s: {}, cfg=cfg, **kw)


class _FakeClock:
    """Scripted time.time() for deterministic step durations."""

    def __init__(self, dts):
        self._t = 0.0
        self._dts = list(dts)
        self._at_start = True

    def time(self):
        if self._at_start:
            self._at_start = False
            return self._t
        self._t += self._dts.pop(0)
        self._at_start = True
        return self._t


# ----------------------------------------------------------------- watchdog

def test_watchdog_ewma_warmup():
    w = StragglerWatchdog(TrainerConfig(total_steps=1, straggler_k=3.0))
    assert w.ewma is None
    assert w.observe(0, 5.0) is False     # first observation only seeds
    assert w.ewma == 5.0
    assert not w.flagged


def test_watchdog_flags_above_threshold():
    w = StragglerWatchdog(TrainerConfig(total_steps=1, straggler_k=3.0,
                                        straggler_ewma=0.9))
    w.observe(0, 1.0)
    assert w.observe(1, 2.9) is False     # below 3x
    assert w.observe(2, 10.0) is True     # way above 3x EWMA
    assert w.flagged and w.flagged[0][0] == 2


def test_watchdog_ewma_update_formula():
    w = StragglerWatchdog(TrainerConfig(total_steps=1, straggler_ewma=0.9))
    w.observe(0, 1.0)
    w.observe(1, 2.0)
    assert w.ewma == pytest.approx(0.9 * 1.0 + 0.1 * 2.0)


def test_watchdog_slow_step_still_updates_ewma():
    w = StragglerWatchdog(TrainerConfig(total_steps=1, straggler_k=3.0,
                                        straggler_ewma=0.9))
    w.observe(0, 1.0)
    assert w.observe(1, 10.0) is True
    assert w.ewma == pytest.approx(0.9 * 1.0 + 0.1 * 10.0)


def test_on_straggler_checkpoint_action(tmp_path, monkeypatch):
    cfg = TrainerConfig(total_steps=5, ckpt_dir=str(tmp_path),
                        ckpt_every=1000, straggler_k=3.0,
                        on_straggler="checkpoint")
    t = _trainer(cfg)
    # steps 0-2 take 1s, step 3 takes 30s (straggler), step 4 normal
    monkeypatch.setattr(trainer_mod, "time", _FakeClock([1, 1, 1, 30, 1]))
    t.run(resume=False)
    assert t.watchdog.flagged and t.watchdog.flagged[0][0] == 3
    # the straggler action cut a checkpoint at the flagged step (the final
    # end-of-run save at step 5 also exists; ckpt_every itself never hit)
    assert (tmp_path / "step_00000003").is_dir()


def test_on_straggler_log_does_not_checkpoint(tmp_path, monkeypatch):
    cfg = TrainerConfig(total_steps=5, ckpt_dir=None, ckpt_every=1000,
                        straggler_k=3.0, on_straggler="log")
    t = _trainer(cfg)
    monkeypatch.setattr(trainer_mod, "time", _FakeClock([1, 1, 1, 30, 1]))
    t.run(resume=False)
    assert t.watchdog.flagged
    assert ckpt_mod.latest_step(str(tmp_path)) is None


# ------------------------------------------------------------------- resume

def test_try_resume_roundtrip(tmp_path):
    t1 = _trainer(TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path)))
    t1.state = {"params": {"w": jnp.asarray([9.0, 8.0, 7.0, 6.0])},
                "step": jnp.asarray(7, jnp.int32)}
    t1._save(7)
    t2 = _trainer(TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path)))
    assert t2.start_step == 0
    t2._try_resume()
    assert t2.start_step == 7
    np.testing.assert_array_equal(np.asarray(t2.state["params"]["w"]),
                                  [9.0, 8.0, 7.0, 6.0])


def test_try_resume_noop_without_ckpt(tmp_path):
    t = _trainer(TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path)))
    t._try_resume()                        # empty dir: no-op
    assert t.start_step == 0
    t2 = _trainer(TrainerConfig(total_steps=10, ckpt_dir=None))
    t2._try_resume()
    assert t2.start_step == 0


# --------------------------------------------------- batched host transfers

def test_single_device_get_per_step(monkeypatch):
    t = _trainer(TrainerConfig(total_steps=6, log_every=2))
    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    t.run(resume=False)
    assert calls["n"] == 6                 # exactly one fetch per step


def test_grad_norm_only_on_logged_steps():
    t = _trainer(TrainerConfig(total_steps=7, log_every=3))
    hist = t.run(resume=False)
    recs = {h["step"]: h for h in hist if "loss" in h}
    assert set(recs) == set(range(7))
    for step, rec in recs.items():
        if step % 3 == 0:
            assert rec["grad_norm"] == pytest.approx(0.5)
        else:
            assert "grad_norm" not in rec


def test_loss_always_fetched():
    t = _trainer(TrainerConfig(total_steps=4, log_every=100))
    hist = t.run(resume=False)
    assert all(h["loss"] == 1.0 for h in hist if "loss" in h)
