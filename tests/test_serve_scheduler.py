"""Slot-scheduler unit tests + the engine-level fuzz: random
submit/poll/cancel/step interleavings through a live ServeEngine, with the
allocator/page-table/scheduler invariants checked after every transition
(the `slow`-marked fuzz runs in the non-blocking CI job).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import BF16
from repro.models import build_model
from repro.serve import ServeEngine, SlotScheduler
from repro.serve.scheduler import DONE, EVICTED, QUEUED, RUNNING

POLICY = BF16.replace(compute="float32")


# ------------------------------------------------------------ scheduler unit

def test_fifo_admission_and_slot_reuse():
    s = SlotScheduler(2)
    r = [s.submit([1], 4, now=0) for _ in range(4)]
    assert s.place(s.admissible()) == 0
    assert s.place(s.admissible()) == 1
    assert s.admissible() is None                     # slots full
    s.finish(s.requests[r[0]])
    req = s.admissible()
    assert req.rid == r[2]                            # FIFO order
    assert s.place(req) == 0                          # freed slot reused
    s.check_invariants()


def test_cancel_queued_and_running():
    s = SlotScheduler(1)
    r0 = s.submit([1], 4, now=0)
    r1 = s.submit([2], 4, now=0)
    s.place(s.admissible())
    assert s.cancel(r1)                               # still queued
    assert s.requests[r1].state == EVICTED
    assert s.cancel(r0)                               # running
    assert s.requests[r0].state == EVICTED
    assert not s.cancel(r0)                           # already finished
    assert not s.busy
    s.check_invariants()


def test_timeout_detection():
    s = SlotScheduler(1)
    rid = s.submit([1], 10, now=0, timeout_steps=2)
    s.place(s.admissible())
    assert not s.timed_out()
    s.requests[rid].decode_steps = 2
    assert [r.rid for r in s.timed_out()] == [rid]


def test_status_vocabulary():
    s = SlotScheduler(1)
    rid = s.submit([1, 2], 3, now=5)
    st = s.status(rid)
    assert st["state"] == QUEUED and st["submit_step"] == 5
    req = s.admissible()
    s.place(req)
    req.tokens.append(7)
    req.first_token_step = 6
    assert s.status(rid)["state"] == RUNNING
    s.finish(req)
    st = s.status(rid)
    assert st["state"] == DONE and st["tokens"] == [7]
    assert st["first_token_step"] == 6


# -------------------------------------------------------------- engine fuzz

@pytest.mark.slow
def test_engine_fuzz_invariants():
    """Random interleavings of submit/step/cancel against a real model:
    scheduler + allocator + page-table invariants hold at every step, all
    requests terminate, and pages fully drain back to the allocator."""
    cfg = get_config("llama2-400m", smoke=True).replace(
        cache_dtype="float32", remat=False)
    model = build_model(cfg, POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    for trial in range(3):
        eng = ServeEngine(model, params, n_slots=3, max_len=32,
                          prefill_len=8, page_size=int(rng.integers(1, 6)),
                          n_pages=int(rng.integers(8, 40)),
                          default_timeout_steps=12)
        rids = []
        for _ in range(60):
            u = rng.random()
            if u < 0.35 and len(rids) < 12:
                prompt = rng.integers(1, cfg.vocab_size,
                                      size=int(rng.integers(1, 8))).tolist()
                rids.append(eng.submit(prompt,
                                       int(rng.integers(1, 10))))
            elif u < 0.45 and rids:
                eng.cancel(int(rng.choice(rids)))
            else:
                eng.step()
            eng.check_invariants()
            for rid in rids:
                eng.poll(rid)                         # poll never corrupts
        eng.run(max_steps=200)                        # drain the rest
        eng.check_invariants()
        assert eng.allocator.available == eng.allocator.n_pages - 1
        states = {eng.poll(r)["state"] for r in rids}
        assert states <= {"done", "evicted"}
