"""Minimal stand-in for the hypothesis API surface this suite uses.

Real hypothesis (see requirements-dev.txt) is preferred and picked up
automatically when installed; this shim keeps the property tests
*runnable* in bare environments by driving each test body with
deterministic seeded samples plus hand-picked adversarial examples
(all-zero arrays, boundary magnitudes, outlier-heavy mixes). No
shrinking, no example database -- a failure reports the offending
example and re-raises the original error.

Supported subset: `given`, `settings(max_examples=, deadline=)`,
`st.floats(min, max, ...)`, `hnp.array_shapes(...)`,
`hnp.arrays(dtype, shapes, elements=...)`.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xF8F4


class _Strategy:
    """A sampler plus a prefix of fixed adversarial examples."""

    def __init__(self, sample, examples=()):
        self.sample = sample
        self.examples = list(examples)

    def example_at(self, i: int, rng) -> object:
        if i < len(self.examples):
            return self.examples[i]
        return self.sample(rng)


class st:
    @staticmethod
    def floats(min_value: float, max_value: float, width=None,
               allow_nan=None, allow_infinity=None, **_):
        lo, hi = float(min_value), float(max_value)
        edges = [lo, hi]
        if lo < 0.0 < hi:
            edges.append(0.0)

        def sample(rng):
            if rng.random() < 0.3:
                # log-uniform magnitudes: cover tiny/huge scales the
                # uniform draw essentially never reaches
                m = 10.0 ** rng.uniform(-6, 4)
                m = m if rng.random() < 0.5 else -m
                if lo <= m <= hi:
                    return float(m)
            return float(rng.uniform(lo, hi))

        return _Strategy(sample, edges)


class hnp:
    @staticmethod
    def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
        def sample(rng):
            nd = int(rng.integers(min_dims, max_dims + 1))
            return tuple(int(rng.integers(min_side, max_side + 1))
                         for _ in range(nd))
        return _Strategy(sample)

    @staticmethod
    def arrays(dtype, shape, elements: _Strategy | None = None):
        dtype = np.dtype(dtype)
        shape_s = shape if isinstance(shape, _Strategy) else \
            _Strategy(lambda rng: tuple(shape))

        def sample(rng):
            shp = shape_s.sample(rng)
            if elements is None:
                return rng.standard_normal(shp).astype(dtype)
            n = int(np.prod(shp)) if shp else 1
            flat = np.array([elements.sample(rng) for _ in range(n)],
                            dtype)
            return flat.reshape(shp)

        fixed = []
        shp0 = shape_s.sample(np.random.default_rng(_SEED))
        fixed.append(np.zeros(shp0, dtype))                  # all-zero
        if elements is not None and len(elements.examples) >= 2:
            lo, hi = elements.examples[0], elements.examples[1]
            fixed.append(np.full(shp0, hi, dtype))           # saturated
            fixed.append(np.full(shp0, lo, dtype))
            outlier = np.full(shp0, hi * 1e-6, dtype)        # outlier-heavy
            outlier.reshape(-1)[0] = hi
            fixed.append(outlier)
        return _Strategy(sample, fixed)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(_SEED)
            for i in range(n):
                vals = [s.example_at(i, rng) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis shim, "
                        f"example {i}): {vals!r}") from e
        wrapper._hypothesis_shim = True
        # hide the example parameters from pytest's fixture resolution
        # (hypothesis proper does the same via its own wrapper)
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper
    return deco
