"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, quantize
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- fp4_quant

@pytest.mark.parametrize("shape", [(8, 128), (256, 256), (300, 512),
                                   (64, 1024), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fp4_quant_matches_ref(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 3).astype(dtype)
    q, s = ops.fp4_quantize(x)
    q_ref, s_ref = ref.fp4_quant_ref(x)
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(s_ref, np.float32), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(q_ref, np.float32))


def test_fp4_quant_outputs_on_grid():
    x = jax.random.normal(KEY, (128, 256)) * 100
    q, s = ops.fp4_quantize(x)
    grid = set(formats.E2M1.values.tolist())
    assert set(np.unique(np.asarray(q, np.float32))).issubset(grid)


# ------------------------------------------------------------ fp4_matmul

@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 512, 256),
                                 (512, 128, 1024), (384, 256, 640)])
def test_fp4_matmul_matches_ref(mnk):
    M, N, K = mnk
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    a_q, sa = quantize.quantize(a, axis=-1)
    w_q, sw = quantize.quantize(w, axis=0)
    got = ops.fp4_matmul_pallas(a_q, w_q, sa, sw, block_m=128, block_n=128,
                                block_k=128)
    want = ref.fp4_matmul_ref(a_q, w_q, sa, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fp4_matmul_equals_core_gemm():
    """Kernel path == simulation path (same quantized operands)."""
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (256, 512))
    w = jax.random.normal(k2, (512, 128))
    a_q, sa = quantize.quantize(a, axis=-1)
    w_q, sw = quantize.quantize(w, axis=0)
    kernel = ops.fp4_matmul_pallas(a_q, w_q, sa, sw)
    sim = (a_q.astype(jnp.float32) @ w_q.astype(jnp.float32)) / sa / sw
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(sim),
                               rtol=1e-4, atol=1e-4)


def test_fp4_matmul_int8_exactness_of_grid_products():
    """E2M1 grid values multiply exactly in int8 (the TPU MXU claim)."""
    vals = jnp.asarray(formats.E2M1.values, jnp.float32)
    a = jnp.tile(vals, (8, 1))                 # (8, 15)
    a = jnp.pad(a, ((0, 0), (0, 113)))         # (8, 128)
    w = jnp.tile(vals[:, None], (1, 128))[:15]
    w = jnp.pad(w, ((0, 113), (0, 0)))         # (128, 128)
    f32 = a @ w
    a8 = formats.to_int8_codes(a)
    w8 = formats.to_int8_codes(w)
    i8 = jnp.matmul(a8, w8, preferred_element_type=jnp.int32) / 4.0
    np.testing.assert_array_equal(np.asarray(f32), np.asarray(i8))


# ---------------------------------------------------------- outlier_clamp

@pytest.mark.parametrize("shape", [(64, 128), (256, 384), (100, 256)])
def test_outlier_clamp_matches_ref(shape):
    x = jax.random.normal(KEY, shape) * 5
    lo, hi = -2.5, 3.0
    c, r = ops.outlier_clamp(x, lo, hi)
    c_ref, r_ref = ref.outlier_clamp_ref(x, lo, hi)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c + r), np.asarray(x), rtol=1e-6)


# -------------------------------------------------------- flash attention

@pytest.mark.parametrize("shape", [(1, 256, 2, 64), (2, 512, 4, 64),
                                   (1, 256, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal):
    B, S, H, D = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, shape, jnp.float32)
    k = jax.random.normal(k2, shape, jnp.float32)
    v = jax.random.normal(k3, shape, jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_block_shape_independence():
    B, S, H, D = 1, 512, 2, 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, H, D))
    v = jax.random.normal(k3, (B, S, H, D))
    a = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    b = ops.flash_attention(q, k, v, block_q=256, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)
