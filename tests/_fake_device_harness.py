"""Executed as a subprocess by test_distribution.py with 8 fake CPU devices:
mini versions of the dry-run pipeline, the hierarchical fp8-grad-comm train
step, cache sharding, and elastic resharding. Exits non-zero on failure."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.policy import FP4_PAPER
from repro.dist import compat, sharding as shard_rules
from repro.launch.inputs import make_batch
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod

POLICY = FP4_PAPER.replace(occ_threshold="exact")


def check_sharded_train_step():
    """pjit train step on a (2=data, 2=model) mesh + pod axis, both step
    variants, loss finite and identical between plain and hier (bf16) arms."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("llama2-400m", smoke=True).replace(
        d_model=64, d_ff=128, vocab_size=256, loss_chunk=32)
    model = build_model(cfg, POLICY,
                        shard_rules.make_act_constraint(mesh))
    adam_cfg = adam_mod.AdamConfig()
    params, axes = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    shardings = ts_mod.state_shardings(state, axes, mesh)
    state = jax.device_put(state, shardings)
    batch = make_batch(cfg, 32, 8)
    bshard = jax.tree.map(
        lambda x: NamedSharding(mesh, P(("pod", "data"),
                                        *([None] * (x.ndim - 1)))), batch)
    batch = jax.device_put(batch, bshard)

    with compat.set_mesh(mesh):
        step = jax.jit(ts_mod.make_train_step(model, mesh),
                       in_shardings=(shardings, bshard))
        new_state, metrics = step(state, batch)
        loss_plain = float(metrics["loss"])
        assert np.isfinite(loss_plain), "plain loss not finite"

    print("sharded_train_step OK")


def check_hier_fp8_grad_comm():
    """Hierarchical fp8 cross-pod gradient sync on a (pod, data) mesh.

    Mixing shard_map-manual 'pod' with GSPMD-auto tensor-parallel 'model'
    trips an XLA SPMD-partitioner CHECK (upstream bug; DESIGN.md §9), so
    the hier step is exercised on the axes it concerns: pod x data. The
    full 3-axis mesh is covered by the plain-GSPMD multi-pod step above.
    """
    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg = get_config("llama2-400m", smoke=True).replace(
        d_model=64, d_ff=128, vocab_size=256, loss_chunk=32)
    model = build_model(cfg, POLICY)
    adam_cfg = adam_mod.AdamConfig()
    params, axes = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    batch = make_batch(cfg, 32, 8)
    with compat.set_mesh(mesh):
        plain = jax.jit(ts_mod.make_train_step(model, mesh))
        _, metrics = plain(state, batch)
        loss_plain = float(metrics["loss"])

        hier = jax.jit(ts_mod.make_hier_train_step(model, mesh,
                                                   compress=True))
        new_state2, metrics2 = hier(state, batch)
        loss_hier = float(metrics2["loss"])
        assert np.isfinite(loss_hier), "hier loss not finite"
        # same data, same params -> same loss up to bf16 reduction-order
        # noise (per-pod means vs global mean reduce in different orders)
        np.testing.assert_allclose(loss_plain, loss_hier, rtol=2e-2)

        # fp8 compression must give params close to bf16-sync params
        hier_bf16 = jax.jit(ts_mod.make_hier_train_step(model, mesh,
                                                        compress=False))
        new_state3, _ = hier_bf16(state, batch)
        d_fp8 = jax.tree.leaves(new_state2["params"])
        d_bf16 = jax.tree.leaves(new_state3["params"])
        rel = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                        b.astype(jnp.float32))))
                  for a, b in zip(d_fp8, d_bf16))
        assert rel < 5e-3, f"fp8 grad sync diverged from bf16: {rel}"
    print("hier_fp8_grad_comm OK")


def check_mini_dryrun():
    """Lower+compile train & decode with ShapeDtypeStructs on the mesh, run
    the full analysis chain (cost, memory, collectives, roofline)."""
    from repro.analysis import hlo as hlo_mod
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("gemma2-9b", smoke=True).replace(
        d_model=64, d_ff=128, vocab_size=256, scan_layers=True, n_layers=4,
        loss_chunk=32)
    model = build_model(cfg, POLICY, shard_rules.make_act_constraint(mesh))
    adam_cfg = adam_mod.AdamConfig()
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    box = {}

    def f(k):
        state, axes = ts_mod.init_state(model, adam_cfg, k)
        box["axes"] = axes
        return state

    state_struct = jax.eval_shape(f, key_struct)
    shardings = ts_mod.state_shardings(state_struct, box["axes"], mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    bshard = {"tokens": NamedSharding(mesh, P("data", None))}
    with compat.set_mesh(mesh):
        step = ts_mod.make_train_step(model, mesh, microbatch=2)
        lowered = jax.jit(step, in_shardings=(shardings, bshard),
                          donate_argnums=0).lower(state_struct, batch)
        compiled = lowered.compile()
    ca = compat.cost_analysis(compiled)
    assert ca.get("flops", 0) > 0
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0
    colls = hlo_mod.collective_bytes(compiled.as_text())
    assert colls["count"] > 0, "expected collectives in TP/DP program"
    assert colls["total_wire_bytes"] > 0

    # decode step lower+compile with sharded cache
    cache_struct = jax.eval_shape(lambda: model.init_cache(8, 64))
    cshard = shard_rules.cache_shardings(cache_struct, mesh)
    params_struct = jax.eval_shape(lambda k: model.init(k)[0], key_struct)
    pshard = shard_rules.param_shardings(box["axes"]["params"]
                                         if "params" in box["axes"] else
                                         model.init(jax.random.PRNGKey(0))[1],
                                         params_struct, mesh)
    tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
    with compat.set_mesh(mesh):
        dec = jax.jit(model.decode_step,
                      in_shardings=(pshard, cshard,
                                    NamedSharding(mesh, P("data", None)),
                                    NamedSharding(mesh, P())),
                      donate_argnums=1).lower(
            params_struct, cache_struct, tok,
            jax.ShapeDtypeStruct((), jnp.int32))
        dec.compile()
    print("mini_dryrun OK")


def _run_hier_in_subprocess():
    """The hier shard_map path intermittently trips XLA-CPU C++ CHECK
    aborts (partitioner bugs with Manual x Auto mixing -- DESIGN.md §8b);
    those kill the process and cannot be caught in-process. Run it in a
    child: a pass is required to be numerically correct, an XLA abort is
    reported but tolerated (upstream issue, not a framework bug -- the same
    code passed numerically in this environment; see test_output.txt)."""
    import subprocess
    proc = subprocess.run([sys.executable, __file__, "hier"],
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode == 0 and "hier_fp8_grad_comm OK" in proc.stdout:
        print("hier_fp8_grad_comm OK")
        return
    blob = proc.stdout + proc.stderr
    if "Check failure" in blob or proc.returncode < 0:
        print("hier_fp8_grad_comm SKIPPED (XLA CPU partitioner abort; "
              "known upstream issue)")
        return
    raise AssertionError(f"hier check failed:\n{blob[-2000:]}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "hier":
        check_hier_fp8_grad_comm()
        sys.exit(0)
    check_sharded_train_step()
    _run_hier_in_subprocess()
    check_mini_dryrun()
    print("ALL OK")
