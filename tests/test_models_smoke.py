"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs + gradients flow. Full configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.policy import FP4_PAPER, BF16
from repro.launch.inputs import make_batch
from repro.models import build_model

SEQ, BATCH = 64, 2

# exact-quantile OCC on tiny tensors; sample mode needs big tensors
SMOKE_POLICY = FP4_PAPER.replace(occ_threshold="exact")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["llama2-400m"])
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, SMOKE_POLICY)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH)

    @jax.jit
    def loss_and_grad(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        return loss, metrics, grads

    loss, metrics, grads = loss_and_grad(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    # loss should be near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)
    gnorms = jax.tree.map(lambda g: float(jnp.linalg.norm(g.astype(jnp.float32))),
                          grads)
    flat = jax.tree.leaves(gnorms)
    assert all(np.isfinite(v) for v in flat), f"{arch}: non-finite grads"
    assert sum(flat) > 0, f"{arch}: all-zero gradients"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_match_params(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, SMOKE_POLICY)
    params, specs = model.init(jax.random.PRNGKey(0))
    pleaves = jax.tree.leaves(params)
    sleaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(pleaves) == len(sleaves)
    for p, s in zip(pleaves, sleaves):
        assert isinstance(s, tuple) and len(s) == p.ndim, (p.shape, s)


@pytest.mark.parametrize("arch", ["llama2-400m", "gemma2-9b", "zamba2-7b",
                                  "rwkv6-1.6b", "qwen3-moe-30b-a3b"])
def test_decode_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, SMOKE_POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(BATCH, 32)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, cache = jax.jit(model.decode_step)(params, cache, tok,
                                               jnp.int32(0))
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    logits2, _ = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(1))
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_bf16_baseline_runs():
    cfg = get_config("llama2-400m", smoke=True)
    model = build_model(cfg, BF16)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH)
    loss, _ = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))


def test_whisper_decode_with_cross_attention():
    cfg = get_config("whisper-medium", smoke=True)
    model = build_model(cfg, SMOKE_POLICY)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SEQ, BATCH)
    cache = model.init_cache(BATCH, 32, memory_len=SEQ // 2)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (BATCH, cfg.vocab_size)
    tok = jnp.ones((BATCH, 1), jnp.int32)
    logits, _ = jax.jit(model.decode_step)(
        params, cache, tok, jnp.int32(SEQ // 2))
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
