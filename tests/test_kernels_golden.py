"""Golden-value + parity tests for the Pallas FP4 kernels (interpret mode)
against `kernels/ref.py`, on fixed seeds, with stored per-dtype tolerances.

The golden rows are hand-derived from the format grids: each input row's
absmax equals the format max so the quantization scale is exactly 1 and
the expected on-grid outputs can be read off the boundary table.
Tie-breaking on a boundary follows searchsorted(side="right"): the value
rounds UP (toward +inf) -- +0.25 -> 0.5 but -0.25 -> 0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, quantize
from repro.kernels import ref
from repro.kernels.fp4_matmul import fp4_matmul_kernel
from repro.kernels.fp4_quant import fp4_quant, quant_stats

# Stored tolerances: (format, dtype) -> abs tolerance on the on-grid
# output. Kernel and reference share the exact same f32 scaling + boundary
# decisions, so parity is bit-exact for both input dtypes.
TOLERANCES = {
    ("e2m1", "float32"): 0.0,
    ("e2m1", "bfloat16"): 0.0,
    ("e1m2", "float32"): 0.0,
    ("e1m2", "bfloat16"): 0.0,
}

# --------------------------------------------------------------- golden rows
# E2M1 grid: 0 .5 1 1.5 2 3 4 6; boundaries .25 .75 1.25 1.75 2.5 3.5 5
GOLDEN_E2M1 = [
    ([0.1, 0.24, 0.26, 1.1, 2.4, 2.6, 5.1, -6.0],
     [0.0, 0.0, 0.5, 1.0, 2.0, 3.0, 6.0, -6.0]),
    # boundary ties round toward +inf on both signs
    ([0.25, -0.25, 2.5, 3.5, 5.0, -5.0, -2.5, 6.0],
     [0.5, 0.0, 3.0, 4.0, 6.0, -4.0, -2.0, 6.0]),
    # absmax 3 -> scale 2; on-grid output is x*2 for exact grid points
    ([0.5, 1.0, 1.5, 3.0, -3.0, 0.0, 2.0, 0.75],
     [1.0, 2.0, 3.0, 6.0, -6.0, 0.0, 4.0, 1.5]),
]

# E1M2 grid: 0 .5 1 1.5 2 2.5 3 3.5; boundaries .25 .75 ... 3.25
GOLDEN_E1M2 = [
    ([0.2, 0.3, 1.2, 2.24, 2.26, 3.3, -3.5, 3.5],
     [0.0, 0.5, 1.0, 2.0, 2.5, 3.5, -3.5, 3.5]),
    ([0.25, -0.25, 3.25, -3.25, 0.75, 1.75, -1.75, 3.5],
     [0.5, 0.0, 3.5, -3.0, 1.0, 2.0, -1.5, 3.5]),
]


@pytest.mark.parametrize("row,expected", GOLDEN_E2M1)
def test_golden_e2m1_ref(row, expected):
    q, scale = ref.fp4_quant_ref(jnp.asarray([row], jnp.float32))
    np.testing.assert_array_equal(np.asarray(q)[0], np.asarray(expected))


@pytest.mark.parametrize("row,expected", GOLDEN_E2M1)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_golden_e2m1_pallas_kernel(row, expected, dtype):
    x = jnp.asarray([row], jnp.dtype(dtype))
    q, scale = fp4_quant(x, interpret=True)
    tol = TOLERANCES[("e2m1", dtype)]
    np.testing.assert_allclose(np.asarray(q, np.float32)[0],
                               np.asarray(expected), atol=tol)


@pytest.mark.parametrize("row,expected", GOLDEN_E1M2)
def test_golden_e1m2_ref(row, expected):
    q, scale = quantize.quantize(jnp.asarray([row], jnp.float32),
                                 axis=-1, fmt=formats.E1M2)
    tol = TOLERANCES[("e1m2", "float32")]
    np.testing.assert_allclose(np.asarray(q)[0], np.asarray(expected),
                               atol=tol)


def test_golden_scales():
    # absmax == fmt max -> scale exactly 1; absmax 3 -> scale 2 (e2m1)
    x = jnp.asarray([[1.0, -6.0, 2.0, 0.3], [0.5, 1.0, 1.5, 3.0]],
                    jnp.float32)
    _, s_ref = ref.fp4_quant_ref(x)
    _, s_ker = fp4_quant(x, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), [[1.0], [2.0]])
    np.testing.assert_array_equal(np.asarray(s_ker), [[1.0], [2.0]])


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_quant_kernel_parity_random(seed, dtype):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_t(3.0, size=(48, 96)), jnp.dtype(dtype))
    q_k, s_k = fp4_quant(x, interpret=True)
    q_r, s_r = ref.fp4_quant_ref(x)
    tol = TOLERANCES[("e2m1", dtype)]
    np.testing.assert_allclose(np.asarray(q_k, np.float32),
                               np.asarray(q_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=0)


def test_quant_kernel_degenerate_rows():
    # all-zero row -> scale 1, q 0; constant row maps to the format max
    x = jnp.zeros((4, 16), jnp.float32).at[1].set(0.375)
    q, s = fp4_quant(x, interpret=True)
    q_r, s_r = ref.fp4_quant_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(q)[0], 0.0)
    np.testing.assert_array_equal(np.asarray(q)[1], 6.0)


# ------------------------------------------------------------------- matmul

def test_matmul_golden_single_tile():
    """K fits one tile: kernel accumulation order == ref, exact equality.
    Hand value: a=[2,3], w=[[1],[6]] on grid, sa=2, sw=0.5 ->
    (2*1 + 3*6)/(2*0.5) = 20."""
    a_q = jnp.asarray([[2.0, 3.0]], jnp.float32)
    w_q = jnp.asarray([[1.0], [6.0]], jnp.float32)
    sa = jnp.asarray([[2.0]], jnp.float32)
    sw = jnp.asarray([[0.5]], jnp.float32)
    out = fp4_matmul_kernel(a_q, w_q, sa, sw, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), [[20.0]])


@pytest.mark.parametrize("seed", [0, 7])
def test_matmul_parity_random(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    a_q, sa = quantize.quantize(a, axis=-1)
    w_q, sw = quantize.quantize(w, axis=0)
    out_k = fp4_matmul_kernel(a_q, w_q, sa, sw, interpret=True)
    out_r = ref.fp4_matmul_ref(a_q, w_q, sa, sw)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_matmul_parity_multi_k_tile(seed=3):
    """K > block_k: per-tile f32 accumulation vs one jnp.matmul -- order
    differs, bound the drift instead of demanding bit equality."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((16, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((96, 8)), jnp.float32)
    a_q, sa = quantize.quantize(a, axis=-1)
    w_q, sw = quantize.quantize(w, axis=0)
    out_k = fp4_matmul_kernel(a_q, w_q, sa, sw, block_k=32, interpret=True)
    out_r = ref.fp4_matmul_ref(a_q, w_q, sa, sw)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- quant_stats

def test_quant_stats_health_fields():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    q, s = fp4_quant(x, interpret=True)
    stats = {k: float(v) for k, v in quant_stats(x, q, s).items()}
    assert set(stats) == {"mse", "snr_db", "scale_min", "scale_max",
                          "underflow_frac"}
    assert stats["snr_db"] > 6.0          # healthy gaussian tensor
    assert stats["underflow_frac"] == 0.0
    assert stats["scale_min"] <= stats["scale_max"]
    # degenerate input: every row underflows
    tiny = jnp.full((8, 16), 1e-33, jnp.float32)
    q2, s2 = fp4_quant(tiny, interpret=True)
    assert float(quant_stats(tiny, q2, s2)["underflow_frac"]) == 1.0
