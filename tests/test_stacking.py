"""scan-over-layers (stacked) execution must be numerically identical to the
unrolled path -- same math, different program structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import BF16
from repro.launch.inputs import make_batch
from repro.models import build_model
from repro.models.stacking import find_group

POLICY = BF16.replace(compute="float32")


def test_find_group_patterns():
    mk = lambda k, **kw: dict({"kind": k}, **kw)
    assert find_group([mk("attn")] * 8) == (1, 8)
    # gemma2 alternation
    plan = [mk("attn", window=16), mk("attn", window=None)] * 4
    assert find_group(plan) == (2, 4)
    # zamba cadence with remainder
    plan = ([mk("ssm")] * 5 + [mk("shared_attn")]) * 3 + [mk("ssm")] * 2
    assert find_group(plan) == (6, 3)
    # no repetition
    assert find_group([mk("attn"), mk("ssm")]) == (0, 0)


def _stacked_params_from_unrolled(model_u, model_s, params_u):
    """Restack unrolled params into the stacked structure for comparison."""
    from repro.models.stacking import stack_trees
    g, n = model_s.group_size, model_s.n_groups
    layers = params_u["layers"]
    out = {k: v for k, v in params_u.items() if k != "layers"}
    out["stack"] = [stack_trees([layers[k * g + p] for k in range(n)])
                    for p in range(g)]
    out["rest"] = layers[g * n:]
    return out


@pytest.mark.parametrize("arch", ["llama2-400m", "gemma2-9b", "gemma3-27b",
                                  "zamba2-7b", "rwkv6-1.6b",
                                  "qwen3-moe-30b-a3b"])
def test_stacked_loss_matches_unrolled(arch):
    cfg_u = get_config(arch, smoke=True).replace(capacity_factor=8.0)
    cfg_s = cfg_u.replace(scan_layers=True)
    m_u = build_model(cfg_u, POLICY)
    m_s = build_model(cfg_s, POLICY)
    params_u, _ = m_u.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg_u, 32, 2)
    loss_u, _ = m_u.loss(params_u, batch)
    if not m_s.stacked:
        pytest.skip(f"{arch}: no repeating group in smoke plan")
    params_s = _stacked_params_from_unrolled(m_u, m_s, params_u)
    loss_s, _ = m_s.loss(params_s, batch)
    np.testing.assert_allclose(float(loss_u), float(loss_s), rtol=1e-5)


@pytest.mark.parametrize("arch", ["llama2-400m", "zamba2-7b", "rwkv6-1.6b"])
def test_stacked_decode_matches_unrolled(arch):
    cfg_u = get_config(arch, smoke=True).replace(cache_dtype="float32")
    cfg_s = cfg_u.replace(scan_layers=True)
    m_u = build_model(cfg_u, POLICY)
    m_s = build_model(cfg_s, POLICY)
    params_u, _ = m_u.init(jax.random.PRNGKey(0))
    if not m_s.stacked:
        pytest.skip(f"{arch}: no repeating group")
    params_s = _stacked_params_from_unrolled(m_u, m_s, params_u)
    B = 2
    tok = jnp.ones((B, 1), jnp.int32)
    cache_u = m_u.init_cache(B, 16)
    cache_s = m_s.init_cache(B, 16)
    for t in range(4):
        lu, cache_u = m_u.decode_step(params_u, cache_u, tok, jnp.int32(t))
        ls, cache_s = m_s.decode_step(params_s, cache_s, tok, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lu), np.asarray(ls), rtol=1e-4,
                                   atol=1e-5)


def test_whisper_stacked_matches_unrolled():
    cfg_u = get_config("whisper-medium", smoke=True)
    cfg_s = cfg_u.replace(scan_layers=True)
    m_u = build_model(cfg_u, POLICY)
    m_s = build_model(cfg_s, POLICY)
    params_u, _ = m_u.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg_u, 32, 2)
    loss_u, _ = m_u.loss(params_u, batch)
    from repro.models.stacking import stack_trees
    params_s = dict(params_u)
    params_s["enc"] = {"stack": stack_trees(params_u["enc"]["layers"]),
                       "ln_post": params_u["enc"]["ln_post"]}
    params_s["dec"] = {"stack": stack_trees(params_u["dec"]["layers"]),
                       "ln_f": params_u["dec"]["ln_f"]}
    loss_s, _ = m_s.loss(params_s, batch)
    np.testing.assert_allclose(float(loss_u), float(loss_s), rtol=1e-5)
