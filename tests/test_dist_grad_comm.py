"""Property tests for the fp8 gradient-comm path (repro.dist.grad_comm).

In-process: round-trip error bounds for the per-tensor-scaled e4m3
compress/decompress across magnitudes, zeros, and outlier-heavy
gradients (hypothesis with the optional-dep fallback shim), plus a
shared-scale multi-pod mean simulation. Multi-device: a subprocess with
--xla_force_host_platform_device_count=8 runs fp8_allreduce_mean /
bf16_allreduce_mean under jax.shard_map and checks them against an
exact ml_dtypes reference and the analytic bound (jax locks the device
count at first init, so the shared pytest process stays at 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests prefer real hypothesis (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:  # bare env: deterministic fallback engine
    from _hypothesis_shim import given, hnp, settings, st

from repro.dist import grad_comm

# e4m3: 3 mantissa bits -> half-ulp <= 2^-4 relative for normals; the
# subnormal floor in scaled space is 2^-10, i.e. amax * 2^-10 / 448
# absolute after unscaling. Tiny slack for the f32 scale itself.
def _roundtrip_bound(x, amax):
    return 0.0625 * np.abs(x) + 2.4e-6 * amax + 1e-30


def _finite_grads():
    return hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=1, max_dims=2, min_side=1, max_side=64),
        elements=st.floats(-1e4, 1e4, width=32, allow_nan=False),
    )


@settings(max_examples=50, deadline=None)
@given(_finite_grads())
def test_fp8_roundtrip_error_bounded(x):
    q, s = grad_comm.fp8_compress(jnp.asarray(x))
    assert q.dtype == jnp.float8_e4m3fn
    back = np.asarray(grad_comm.fp8_decompress(q, s))
    amax = float(np.max(np.abs(x))) if x.size else 0.0
    assert np.all(np.abs(back - x) <= _roundtrip_bound(x, amax))


@settings(max_examples=50, deadline=None)
@given(_finite_grads())
def test_fp8_compress_never_overflows(x):
    q, s = grad_comm.fp8_compress(jnp.asarray(x))
    back = np.asarray(q, dtype=np.float32)
    assert np.all(np.isfinite(back))
    assert np.all(np.abs(back) <= grad_comm.E4M3_MAX)


def test_fp8_zeros_exact():
    q, s = grad_comm.fp8_compress(jnp.zeros((16, 16)))
    assert float(s) == 1.0
    np.testing.assert_array_equal(np.asarray(grad_comm.fp8_decompress(q, s)),
                                  0.0)


@settings(max_examples=25, deadline=None)
@given(_finite_grads(), st.floats(1e-6, 1e6, allow_nan=False))
def test_fp8_shared_scale_multipod_mean(x, pod_scale):
    """Simulated K-pod sync: per-pod grads differ in magnitude, the
    shared (pmax) scale keeps every pod on one grid; mean error obeys
    the elementwise round-trip bound of the worst pod."""
    K = 4
    pods = [x * (pod_scale ** (k / (K - 1) - 0.5)) for k in range(K)]
    amax = max(float(np.max(np.abs(p))) for p in pods) if x.size else 0.0
    outs = []
    for p in pods:
        q, s = grad_comm.fp8_compress(jnp.asarray(p),
                                      amax=jnp.float32(amax))
        outs.append(np.asarray(grad_comm.fp8_decompress(q, s)))
    got = np.mean(outs, axis=0)
    want = np.mean(pods, axis=0)
    bound = np.mean([_roundtrip_bound(p, amax) for p in pods], axis=0)
    assert np.all(np.abs(got - want) <= bound)


def test_fp8_outlier_heavy_gradient():
    # one huge coordinate swamps the shared scale; the rest must still
    # come back within the amax-relative subnormal floor, not explode
    x = np.full((1024,), 1e-3, np.float32)
    x[7] = 1e4
    q, s = grad_comm.fp8_compress(jnp.asarray(x))
    back = np.asarray(grad_comm.fp8_decompress(q, s))
    assert abs(back[7] - 1e4) <= 0.0625 * 1e4
    assert np.all(np.abs(back - x) <= _roundtrip_bound(x, 1e4))


def test_allreduce_mean_single_axis_tracing():
    """Wiring check on a 1-device mesh: shard_map axis of size 1 makes
    both reduces equal the per-tensor round trip."""
    from jax.sharding import PartitionSpec as P
    from repro.dist import compat
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(8, 16)).astype(np.float32))}

    def run(comm_fn):
        f = compat.shard_map(lambda t: comm_fn(t, "pod"), mesh=mesh,
                             in_specs=(jax.tree.map(lambda _: P(), g),),
                             out_specs=jax.tree.map(lambda _: P(), g))
        return np.asarray(f(g)["w"])

    amax = float(np.max(np.abs(g["w"])))
    got8 = run(grad_comm.fp8_allreduce_mean)
    assert np.all(np.abs(got8 - np.asarray(g["w"]))
                  <= _roundtrip_bound(np.asarray(g["w"]), amax))
    got16 = run(grad_comm.bf16_allreduce_mean)
    np.testing.assert_allclose(got16, np.asarray(g["w"]), rtol=8e-3)


_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compat, grad_comm
from repro.launch.mesh import make_mesh

K = 8
rng = np.random.default_rng(42)
# outlier-heavy, per-pod magnitude spread
x = rng.normal(size=(K, 4, 16)).astype(np.float32)
x *= np.logspace(-2, 2, K, dtype=np.float32)[:, None, None]
x[0, 0, 0] = 1e4

mesh = make_mesh((K,), ("pod",))
flat = jnp.asarray(x.reshape(K * 4, 16))  # shard_map splits dim 0

def per_pod(fn):
    f = compat.shard_map(lambda g: fn(g, "pod"), mesh=mesh,
                         in_specs=(P("pod"),), out_specs=P())
    return np.asarray(jax.jit(f)(flat))

got8 = per_pod(grad_comm.fp8_allreduce_mean)
got16 = per_pod(grad_comm.bf16_allreduce_mean)

# independent reference of the wire algorithm via ml_dtypes; XLA CPU
# converts through f16 (double rounding) so allow one e4m3 ulp per pod
amax = np.max(np.abs(x))
scale = np.float32(448.0) / amax
deq = (x * scale).astype(ml_dtypes.float8_e4m3fn).astype(np.float32)
want8 = deq.sum(0) / (scale * K)
ulp = np.mean(0.125 * np.abs(x) + 5e-6 * amax, axis=0)
assert np.all(np.abs(got8 - want8) <= ulp), "fp8 mean != wire reference"

want = x.astype(np.float64).mean(0).astype(np.float32)
bound = np.mean(0.0625 * np.abs(x) + 2.4e-6 * amax, axis=0)
assert np.all(np.abs(got8 - want) <= bound), "fp8 mean outside bound"

# bf16 arm: psum accumulates in bf16 in XLA, so bound analytically
# (cast error + up to 7 bf16 adds) instead of matching a summation order
tol16 = 2.0 ** -5 * np.abs(x).sum(0) / K + 1e-8
assert np.all(np.abs(got16 - want) <= tol16), "bf16 mean outside bound"
print("GRAD_COMM_OK")
"""


def test_fp8_allreduce_shard_map_8_fake_devices():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    proc = subprocess.run([sys.executable, "-c", _CHILD.format(src=src)],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"child failed:\nstdout:\n{proc.stdout[-2000:]}\n" \
        f"stderr:\n{proc.stderr[-2000:]}"
    assert "GRAD_COMM_OK" in proc.stdout
