"""Property tests for the serve-side page allocator and page table
(serve/paged_cache.py): no double-allocation, all pages returned on
release, no dangling page-table entries -- driven by hypothesis (or the
deterministic shim) through random alloc/free/reserve/release programs.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:                                        # pragma: no cover
    from _hypothesis_shim import given, settings, st, hnp

from repro.serve.paged_cache import (PageAllocator, PageTable, TRASH_PAGE,
                                     pages_needed)

# Entropy source compatible with both real hypothesis and the shim: a
# float array in [0,1) drives op selection and op arguments.
_OPS = hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=1,
                                               min_side=1, max_side=120),
                  elements=st.floats(min_value=0.0, max_value=0.999))


def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2
    assert pages_needed(17, 16) == 2


# ------------------------------------------------------------ raw allocator

@settings(max_examples=40, deadline=None)
@given(_OPS)
def test_allocator_program_invariants(ops):
    alloc = PageAllocator(n_pages=17, page_size=4)
    held: list[list[int]] = []
    for u in np.asarray(ops, np.float64):
        if u < 0.55 or not held:                      # alloc 0..4 pages
            n = int(u * 1000) % 5
            pages = alloc.alloc(n)
            if pages is None:
                assert n > alloc.available
            else:
                assert len(pages) == n
                assert TRASH_PAGE not in pages
                # no double allocation: disjoint from everything held
                flat = {p for g in held for p in g}
                assert not (set(pages) & flat)
                assert len(set(pages)) == n
                held.append(pages)
        else:                                         # free one held group
            idx = int(u * 1000) % len(held)
            alloc.free(held.pop(idx))
        alloc.check_invariants()
    for g in held:                                    # full teardown
        alloc.free(g)
    alloc.check_invariants()
    assert alloc.available == alloc.n_pages - 1       # everything returned


def test_allocator_rejects_double_free_and_trash():
    alloc = PageAllocator(n_pages=5, page_size=2)
    pages = alloc.alloc(2)
    alloc.free(pages)
    with pytest.raises(ValueError):
        alloc.free(pages)                             # double free
    with pytest.raises(ValueError):
        alloc.free([TRASH_PAGE])                      # reserved page
    with pytest.raises(ValueError):
        alloc.free([99])                              # foreign page


def test_allocator_all_or_nothing():
    alloc = PageAllocator(n_pages=4, page_size=2)     # 3 usable pages
    assert alloc.alloc(4) is None
    assert alloc.available == 3                       # nothing leaked
    assert alloc.alloc(3) is not None
    assert alloc.alloc(1) is None


# ---------------------------------------------------------------- page table

@settings(max_examples=40, deadline=None)
@given(_OPS)
def test_page_table_program_invariants(ops):
    """reserve/advance/release interleavings across slots: entries never
    dangle, release returns every page, growth is all-or-nothing."""
    alloc = PageAllocator(n_pages=13, page_size=4)
    table = PageTable(alloc, n_slots=3, max_pages_per_slot=4)
    for u in np.asarray(ops, np.float64):
        slot = int(u * 1000) % 3
        op = int(u * 7919) % 3
        if op == 0:                                   # grow by 1..5 tokens
            n = 1 + int(u * 31) % 5
            before = alloc.available
            if not table.reserve(slot, n):
                assert alloc.available == before      # all-or-nothing
        elif op == 1 and table.seq_lens[slot] < 16:
            if table.reserve(slot, 1):
                table.advance(slot, 1)                # decode-style write
        else:
            table.release(slot)                       # completion/eviction
        table.check_invariants()
    for s in range(3):
        table.release(s)
    table.check_invariants()
    assert alloc.available == alloc.n_pages - 1
    assert (table.table == -1).all()


def test_page_table_release_clears_slot():
    alloc = PageAllocator(n_pages=9, page_size=2)
    table = PageTable(alloc, n_slots=2, max_pages_per_slot=4)
    assert table.reserve(0, 5)                        # 3 pages
    table.advance(0, 5)
    assert len(table.slot_pages(0)) == 3
    table.release(0)
    assert table.slot_pages(0) == []
    assert table.seq_lens[0] == 0
    assert alloc.available == 8
    table.check_invariants()


def test_page_table_respects_max_pages_per_slot():
    alloc = PageAllocator(n_pages=32, page_size=2)
    table = PageTable(alloc, n_slots=1, max_pages_per_slot=2)
    assert table.reserve(0, 4)                        # fills both pages
    assert not table.reserve(0, 5)                    # would need a third
    table.check_invariants()
