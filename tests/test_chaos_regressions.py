"""Regression tests for the bugs the chaos harness exposed (DESIGN.md §15).

Every test here fails on the pre-harness code:

  * checkpoint re-save deleted the live step dir *before* the commit
    rename -- a kill in that window lost the step entirely,
  * `restore` raised raw zipfile/json errors on a corrupt checkpoint
    instead of skipping to an older intact one,
  * `make_hier_train_step` hard-coded the shard_map metrics out_specs
    (models emitting extra keys or metrics["obs"] could not run) and
    reported grad_norm as a mean of per-pod norms instead of the norm of
    the accumulated gradient,
  * a corrupt/foreign-version autotune cache crashed kernel launch,
  * `ShardReader` silently served short documents from truncated .bin
    files and raw JSONDecodeErrors from corrupt manifests,
  * `DevicePrefetcher.restart` let a producer stuck past the join
    timeout push stale batches into the new generation, and a producer
    death threw away good batches already queued.
"""
import os
import shutil
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.chaos import hooks
from repro.data.packing import PackedBatch
from repro.data.prefetch import DevicePrefetcher
from repro.data.shards import ShardReader, ShardWriter
from repro.kernels.autotune import AutotuneCache
from repro.optim import adam as adam_mod
from repro.train import checkpoint as ck
from repro.train import train_step as ts


def _state(v, n=4):
    return {"w": np.full((n,), float(v), np.float32), "step": np.int32(5)}


# --------------------------------------------------------------------------
# checkpoint crash windows + corruption
# --------------------------------------------------------------------------

def test_kill_during_resave_never_loses_the_step(tmp_path, monkeypatch):
    """Pre-harness `save` rmtree'd the LIVE step dir before renaming the
    tmp over it; a kill between those syscalls lost the step. The
    park-old protocol only deletes the parked copy after the commit."""
    root = str(tmp_path)
    ck.save(root, 5, _state(1))
    real_rmtree = shutil.rmtree

    def dying_rmtree(path, *a, **kw):
        real_rmtree(path, *a, **kw)
        raise hooks.SimulatedCrash(f"killed right after rmtree({path})")

    monkeypatch.setattr(shutil, "rmtree", dying_rmtree)
    with pytest.raises(hooks.SimulatedCrash):
        ck.save(root, 5, _state(2))
    monkeypatch.setattr(shutil, "rmtree", real_rmtree)
    assert ck.latest_step(root) == 5
    state, _ = ck.restore(root, _state(0))
    assert float(state["w"][0]) == 2.0


def test_restore_skips_corrupt_newest_checkpoint(tmp_path):
    root = str(tmp_path)
    ck.save(root, 2, {"w": np.ones((4,), np.float32), "step": np.int32(2)})
    ck.save(root, 4, {"w": np.ones((4,), np.float32), "step": np.int32(4)})
    npz = os.path.join(root, "step_00000004", "arrays.npz")
    with open(npz, "r+b") as f:
        f.write(b"\xff" * 256)
    with pytest.warns(UserWarning):
        state, _ = ck.restore(
            root, {"w": np.zeros((4,), np.float32), "step": np.int32(0)})
    assert int(state["step"]) == 2
    with pytest.raises(ck.CheckpointError):
        ck.restore(root, {"w": np.zeros((4,), np.float32),
                          "step": np.int32(0)}, step=4)


def test_restore_raises_checkpoint_error_when_all_corrupt(tmp_path):
    root = str(tmp_path)
    ck.save(root, 3, _state(3))
    with open(os.path.join(root, "step_00000003", "manifest.json"),
              "w") as f:
        f.write("{]] not json")
    with pytest.raises(ck.CheckpointError, match="no restorable"):
        with pytest.warns(UserWarning):
            ck.restore(root, _state(0))


# --------------------------------------------------------------------------
# hier train step: eval_shape out_specs + post-accumulation grad_norm
# --------------------------------------------------------------------------

class _Policy:
    def __init__(self, obs):
        self.obs_metrics = obs


class _StubModel:
    """Minimal model.loss contract: grad w.r.t. `w` is mean(batch, 0)."""

    def __init__(self, obs_metrics=False, extra=False):
        self.policy = _Policy(obs_metrics)
        self.extra = extra

    def loss(self, params, batch):
        g = jnp.mean(batch["x"], axis=0)
        loss = jnp.sum(params["w"] * g)
        metrics = {"lm_loss": loss, "aux_loss": jnp.float32(0.0)}
        if self.extra:
            metrics["extra_stat"] = jnp.float32(1.25)
        if self.policy.obs_metrics:
            metrics["obs"] = {"agg/min_snr_db": jnp.float32(12.0)}
        return loss, metrics


def _hier_state(n=4):
    params = {"w": jnp.ones((n,), jnp.float32)}
    return {"params": params,
            "opt": adam_mod.init_state(params, adam_mod.AdamConfig()),
            "step": jnp.zeros((), jnp.int32)}


def _pod_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("pod",))


def test_hier_step_accepts_model_defined_metric_keys():
    """Pre-harness out_specs were a hard-coded 4-key dict; a model
    emitting any extra metric failed shard_map with a tree mismatch."""
    model = _StubModel(extra=True)
    step = ts.make_hier_train_step(model, _pod_mesh(), compress=False)
    state, batch = _hier_state(), {"x": jnp.ones((2, 4), jnp.float32)}
    _, metrics = step(state, batch)
    assert float(metrics["extra_stat"]) == 1.25
    assert {"lm_loss", "aux_loss", "loss", "grad_norm"} <= metrics.keys()


def test_hier_step_supports_obs_metrics():
    """Pre-harness factory raised NotImplementedError under
    policy.obs_metrics; the eval_shape template carries the obs tree."""
    model = _StubModel(obs_metrics=True)
    step = ts.make_hier_train_step(model, _pod_mesh(), compress=False)
    _, metrics = step(_hier_state(), {"x": jnp.ones((2, 4), jnp.float32)})
    assert float(metrics["obs"]["agg/min_snr_db"]) == 12.0


_HIER_GRADNORM_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})

import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.optim import adam as adam_mod
from repro.train import train_step as ts
from test_chaos_regressions import _StubModel

mesh = Mesh(np.array(jax.devices()[:2]), ("pod",))
params = {{"w": jnp.zeros((4,), jnp.float32)}}
state = {{"params": params,
          "opt": adam_mod.init_state(params, adam_mod.AdamConfig()),
          "step": jnp.zeros((), jnp.int32)}}
# pod 0 sees +1 rows, pod 1 sees -1 rows: per-pod grads are +-ones(4)
# (norm 2 each) but the accumulated (pod-mean) gradient is exactly zero.
x = jnp.concatenate([jnp.ones((1, 4)), -jnp.ones((1, 4))]).astype(
    jnp.float32)
step = ts.make_hier_train_step(_StubModel(), mesh, compress=False,
                               clip_norm=1.0)
_, metrics = step(state, {{"x": x}})
gn = float(metrics["grad_norm"])
assert gn < 1e-5, (
    "grad_norm %.4f is a mean of per-pod norms, not the norm of the "
    "accumulated gradient" % gn)
print("HIER_GRADNORM_OK")
"""


@pytest.mark.slow
def test_hier_grad_norm_is_post_allreduce_2_fake_devices():
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, os.pardir, "src"))
    proc = subprocess.run(
        [sys.executable, "-c",
         _HIER_GRADNORM_CHILD.format(src=src, tests=here)],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"child failed:\nstdout:\n{proc.stdout[-2000:]}\n" \
        f"stderr:\n{proc.stderr[-2000:]}"
    assert "HIER_GRADNORM_OK" in proc.stdout


def test_microbatch_grad_norm_is_post_accumulation():
    """Guard: with microbatch accumulation, the clip decision and the
    reported grad_norm are taken on the ACCUMULATED gradient."""
    model = _StubModel()
    step = ts.make_train_step(model, _pod_mesh(), microbatch=2,
                              clip_norm=1e9)
    # microbatch 0 rows are all 3s (grad 3*ones, norm 6); microbatch 1
    # rows are all -1s (grad -ones, norm 2).  Accumulated grad = ones,
    # norm 2.  A mean-of-norms bug would report 4.
    x = jnp.concatenate([jnp.full((2, 4), 3.0), jnp.full((2, 4), -1.0)])
    _, metrics = step(_hier_state(), {"x": x.astype(jnp.float32)})
    assert abs(float(metrics["grad_norm"]) - 2.0) < 0.05


# --------------------------------------------------------------------------
# autotune cache corruption
# --------------------------------------------------------------------------

@pytest.mark.parametrize("payload", [
    b"{]] not json",
    b"[1, 2, 3]",
    b'{"version": 999, "entries": {"x": [64, 64, 64]}}',
    b'{"version": 1, "entries": "not a dict"}',
], ids=["garbage", "json-list", "foreign-version", "entries-not-dict"])
def test_autotune_corrupt_cache_falls_back_with_warning(tmp_path, payload):
    path = tmp_path / "cache.json"
    path.write_bytes(payload)
    cache = AutotuneCache(str(path))
    with pytest.warns(UserWarning, match="empty autotune cache"):
        assert cache.get("q4gemm", "cpu", 128, 128, 128) is None
    cache.put("q4gemm", "cpu", 128, 128, 128, (32, 32, 32))
    assert tuple(AutotuneCache(str(path)).get(
        "q4gemm", "cpu", 128, 128, 128)) == (32, 32, 32)


# --------------------------------------------------------------------------
# shard reader validation
# --------------------------------------------------------------------------

def _tiny_corpus(root, n_docs=8):
    w = ShardWriter(str(root), vocab_size=97, shard_tokens=1 << 20)
    rng = np.random.default_rng(0)
    for _ in range(n_docs):
        w.add_document(rng.integers(1, 97, size=16))
    return w.finalize()


def test_truncated_shard_bin_rejected(tmp_path):
    """memmap slices past EOF clip silently: without the size check a
    truncated .bin served short/empty documents as if nothing happened."""
    manifest = _tiny_corpus(tmp_path)
    r = ShardReader(manifest)
    bin_path = os.path.join(r.root, r.shards[0]["file"])
    with open(bin_path, "r+b") as f:
        f.truncate(os.path.getsize(bin_path) // 2)
    with pytest.raises(ValueError, match="truncated or corrupt"):
        ShardReader(manifest).doc(0)


def test_corrupt_shard_manifest_clean_error(tmp_path):
    manifest = _tiny_corpus(tmp_path)
    with open(manifest, "w") as f:
        f.write("{]] not json")
    with pytest.raises(ValueError, match="corrupt shard manifest"):
        ShardReader(manifest)


def test_shard_manifest_missing_keys_rejected(tmp_path):
    manifest = _tiny_corpus(tmp_path)
    with open(manifest, "w") as f:
        f.write('{"format": "repro-shards-v1", "dtype": "uint16"}')
    with pytest.raises(ValueError, match="missing keys"):
        ShardReader(manifest)


# --------------------------------------------------------------------------
# prefetch generation fence + residual drain
# --------------------------------------------------------------------------

class _GatedStream:
    """Cursor advances before the gated (slow) part of the draw, so a
    reseek is never clobbered -- the generation fence is what's tested."""

    def __init__(self):
        self.i = 0
        self.gate = threading.Event()
        self.gate.set()

    def next_batch(self):
        i = self.i
        self.i = i + 1
        self.gate.wait(20.0)
        return PackedBatch({"tokens": np.full((1, 4), i, np.int32)},
                           {"pack_frac": 1.0})

    def state_dict(self):
        return {"i": self.i}

    def load_state_dict(self, s):
        self.i = int(s["i"])


def test_prefetch_restart_fences_stale_producer():
    """Pre-fence restart reused the shared queue/stop event: a producer
    stuck past the join timeout resumed and pushed a stale batch into
    the post-restart stream."""
    stream = _GatedStream()
    pf = DevicePrefetcher(stream, depth=1, stall_timeout=0.4,
                          join_timeout=0.2)
    assert int(pf.next_batch().arrays["tokens"][0, 0]) == 0
    stream.gate.clear()                    # wedge the producer mid-draw
    with pytest.raises(TimeoutError):
        for _ in range(10):                # drain read-ahead, then stall
            pf.next_batch()
    pf.restart({"i": 100})                 # old producer still wedged
    stream.gate.set()                      # release the zombie
    got = [int(pf.next_batch().arrays["tokens"][0, 0]) for _ in range(3)]
    assert got == [100, 101, 102], got
    pf.stop()


def test_prefetch_drains_residual_batches_before_surfacing_death():
    """Batches the producer queued before dying are still valid (and
    checkpoint-consistent); the death must surface only once the queue
    is dry -- previously a good staged batch was thrown away."""

    class DyingStream:
        def __init__(self):
            self.i = 0

        def next_batch(self):
            if self.i >= 2:
                raise OSError("disk vanished")
            i = self.i
            self.i += 1
            return PackedBatch({"tokens": np.full((1, 4), i, np.int32)},
                               {"pack_frac": 1.0})

        def state_dict(self):
            return {"i": self.i}

        def load_state_dict(self, s):
            self.i = int(s["i"])

    pf = DevicePrefetcher(DyingStream(), depth=1, stall_timeout=2.0)
    served = []
    with pytest.raises(RuntimeError, match="producer died") as ei:
        for _ in range(5):
            served.append(int(pf.next_batch().arrays["tokens"][0, 0]))
    assert served == [0, 1]
    assert isinstance(ei.value.__cause__, OSError)
    pf.stop()
