"""Shared pytest setup.

* Puts `src/` on sys.path so `python -m pytest` works without a manual
  PYTHONPATH (the tier-1 command in ROADMAP.md keeps setting it; both
  work).
* Puts the tests dir itself on sys.path so test modules can import the
  `_hypothesis_shim` fallback regardless of pytest import mode.
* Registers the `slow` marker used by the multi-device subprocess
  harnesses (tests/test_distribution.py), so `-m "not slow"` selects the
  fast tier and no PytestUnknownMarkWarning fires.
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
for _p in (os.path.join(_HERE, os.pardir, "src"), _HERE):
    _p = os.path.abspath(_p)
    if _p not in sys.path:
        sys.path.insert(0, _p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-device subprocess harnesses "
        "(deselect with -m \"not slow\")")
