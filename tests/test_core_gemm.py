"""fp4_matmul / fp4_linear: forward semantics + the paper's exact backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dge, formats, occ, quantize
from repro.core.fp4_gemm import fp4_matmul
from repro.core.linear import fp4_linear
from repro.core.policy import BF16, FP4_PAPER, TENSOR_WISE, W4A4_DIRECT, QuantPolicy

KEY = jax.random.PRNGKey(0)


def _rand(shape, key, scale=1.0):
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def test_fp4_matmul_forward_matches_manual_reference():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((8, 16), k1), _rand((16, 4), k2)
    pol = FP4_PAPER.replace(occ=False, compute="float32")
    got = fp4_matmul(a, w, pol)
    # manual: quantize, matmul, rescale
    sa = quantize.absmax_scale(a, -1, 6.0)
    sw = quantize.absmax_scale(w, 0, 6.0)
    aq = quantize.lut_round(a * sa)
    wq = quantize.lut_round(w * sw)
    want = (aq @ wq) / sa / sw
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=1e-4)


def test_int8_backend_bit_identical_to_sim():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((32, 64), k1), _rand((64, 16), k2)
    pol = FP4_PAPER.replace(occ=False, compute="float32")
    y_sim = fp4_matmul(a, w, pol)
    y_int8 = fp4_matmul(a, w, pol.replace(gemm_backend="int8"))
    np.testing.assert_allclose(np.asarray(y_sim), np.asarray(y_int8),
                               rtol=1e-5, atol=1e-5)


def test_backward_matches_paper_eq22():
    """dW must equal (A_dq^T @ g) * f'(W_scaled); dA must equal g @ W_dq^T."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((8, 16), k1), _rand((16, 4), k2)
    pol = FP4_PAPER.replace(occ=False, compute="float32")

    y, vjp = jax.vjp(lambda a, w: fp4_matmul(a, w, pol), a, w)
    g = jnp.ones_like(y)
    da, dw = vjp(g)

    sa = quantize.absmax_scale(a, -1, 6.0)
    sw = quantize.absmax_scale(w, 0, 6.0)
    a_dq = quantize.lut_round(a * sa) / sa
    w_dq = quantize.lut_round(w * sw) / sw
    want_dw = (a_dq.T @ g) * dge.dge_derivative(w * sw, k=5.0, clip=3.0)
    want_da = g @ w_dq.T
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want_dw), rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(np.asarray(da), np.asarray(want_da), rtol=2e-2, atol=2e-3)


def test_ste_vs_dge_gradients_differ():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((8, 16), k1), _rand((16, 4), k2)
    def grad_w(pol):
        return jax.grad(lambda w: jnp.sum(fp4_matmul(a, w, pol)))(w)
    g_dge = grad_w(FP4_PAPER.replace(occ=False))
    g_ste = grad_w(W4A4_DIRECT)
    assert not np.allclose(np.asarray(g_dge), np.asarray(g_ste))


def test_disabled_policy_is_plain_matmul():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((8, 16), k1), _rand((16, 4), k2)
    got = fp4_matmul(a, w, BF16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(a @ w), rtol=1e-2)


def test_tensor_wise_higher_error_with_outliers():
    """Fig. 6d: vector-wise beats tensor-wise under per-row dynamic range."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = _rand((64, 128), k1)
    a = a.at[5].mul(100.0)  # one hot row blows up tensor-wise scaling
    w = _rand((128, 32), k2, 0.1)
    exact = np.asarray(a @ w)
    pol = FP4_PAPER.replace(occ=False, compute="float32")
    err_vec = np.linalg.norm(np.asarray(fp4_matmul(a, w, pol)) - exact)
    err_ten = np.linalg.norm(
        np.asarray(fp4_matmul(a, w, TENSOR_WISE.replace(occ=False, compute="float32"))) - exact)
    assert err_vec < err_ten


@pytest.mark.parametrize("backend", ["bf16_sim", "int8"])
def test_tensor_wise_scalar_rescale_matches_manual(backend):
    """Tensor-wise (a_axis=w_axis=None) rescale must be the same
    divide-by-scale chain as the vector-wise path -- the old code
    special-cased scalar sa with a reciprocal multiply whose extra
    rounding made this arm drift from kernels/ref.py."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1, 3.0), _rand((32, 8), k2)
    pol = TENSOR_WISE.replace(occ=False, compute="float32",
                              gemm_backend=backend)
    got = fp4_matmul(a, w, pol)
    sa = quantize.absmax_scale(a, None, 6.0)
    sw = quantize.absmax_scale(w, None, 6.0)
    want = (quantize.lut_round(a * sa) @ quantize.lut_round(w * sw)) / sa / sw
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fp4_linear_occ_dense_and_channel_and_bias():
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = _rand((32, 64), k1)
    a = a.at[:, 3].mul(80.0)  # channel outlier
    w = _rand((64, 16), k2, 0.1)
    b = _rand((16,), k3)
    exact = np.asarray(a @ w + b)
    for comp in ["dense", "channel", "none"]:
        pol = FP4_PAPER.replace(occ_comp=comp, occ_threshold="exact",
                                compute="float32")
        y = np.asarray(fp4_linear(a, w, b, policy=pol))
        assert y.shape == exact.shape and np.all(np.isfinite(y))
    err_dense = np.linalg.norm(np.asarray(fp4_linear(
        a, w, b, policy=FP4_PAPER.replace(occ_comp="dense", occ_threshold="exact",
                                          compute="float32"))) - exact)
    err_none = np.linalg.norm(np.asarray(fp4_linear(
        a, w, b, policy=FP4_PAPER.replace(occ_comp="none", occ_threshold="exact",
                                          compute="float32"))) - exact)
    assert err_dense < err_none  # compensation must help


def test_occ_improves_gemm_accuracy_with_outliers():
    k1, k2 = jax.random.split(KEY)
    a = _rand((64, 128), k1)
    a = a.at[:, 7].mul(60.0)
    w = _rand((128, 32), k2, 0.1)
    exact = np.asarray(a @ w)
    pol_occ = FP4_PAPER.replace(occ_threshold="exact", compute="float32")
    pol_no = FP4_PAPER.replace(occ=False, compute="float32")
    err_occ = np.linalg.norm(np.asarray(fp4_linear(a, w, policy=pol_occ)) - exact)
    err_no = np.linalg.norm(np.asarray(fp4_linear(a, w, policy=pol_no)) - exact)
    assert err_occ < err_no


def test_grad_flows_through_occ_paths():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1), _rand((32, 8), k2)
    pol = FP4_PAPER.replace(occ_threshold="exact", compute="float32")
    da, dw = jax.grad(lambda a, w: jnp.sum(fp4_linear(a, w, policy=pol)),
                      argnums=(0, 1))(a, w)
    assert np.all(np.isfinite(np.asarray(da)))
    assert np.all(np.isfinite(np.asarray(dw)))
    assert float(jnp.linalg.norm(dw)) > 0


def test_batched_3d_activation_shapes():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((4, 8, 32), k1), _rand((32, 16), k2)
    y = fp4_linear(a, w, policy=FP4_PAPER.replace(compute="float32"))
    assert y.shape == (4, 8, 16)
