"""Chaos harness tests: seam mechanics, scenario runner, CLI, env-kill.

The fast scenario set (12 seeded fault scenarios, pure host numpy) runs
in-process here, so tier-1 exercises the same invariants CI's chaos job
does: kill-mid-checkpoint resume token-identity, debris cleanup,
sentinel trip -> bf16 fallback, corruption rejection, prefetch fencing.
The subprocess/serve set rides the `slow` marker.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.chaos import hooks, scenarios
from repro.chaos.__main__ import main as chaos_main


# --------------------------------------------------------------------------
# seam mechanics
# --------------------------------------------------------------------------

def test_chaos_point_identity_when_disarmed():
    hooks.clear()
    assert hooks.chaos_point("no.such.point", 42, step=7) == 42
    assert hooks.chaos_point("no.such.point") is None


def test_installed_scopes_handler_even_on_crash():
    with hooks.installed("t.point", lambda v, **k: v + 1):
        assert hooks.chaos_point("t.point", 1) == 2
    assert hooks.chaos_point("t.point", 1) == 1
    with pytest.raises(hooks.SimulatedCrash):
        with hooks.installed("t.point", hooks.crash_handler()):
            hooks.chaos_point("t.point")
    assert hooks.chaos_point("t.point", 3) == 3   # uninstalled despite crash


def test_crash_handler_fires_on_nth_hit():
    h = hooks.crash_handler(nth=3)
    with hooks.installed("t.nth", h):
        hooks.chaos_point("t.nth")
        hooks.chaos_point("t.nth")
        with pytest.raises(hooks.SimulatedCrash):
            hooks.chaos_point("t.nth")


def test_handlers_chain_in_install_order():
    with hooks.installed("t.chain", lambda v, **k: v + "a"):
        with hooks.installed("t.chain", lambda v, **k: v + "b"):
            assert hooks.chaos_point("t.chain", "x") == "xab"


# --------------------------------------------------------------------------
# scenario registry + runner
# --------------------------------------------------------------------------

def test_names_selectors():
    fast = scenarios.names("fast")
    assert "kill_mid_checkpoint_resume" in fast
    assert len(fast) >= 6                       # acceptance floor
    assert set(fast) <= set(scenarios.names("full"))
    assert scenarios.names("ckpt,serve")        # tag mix resolves
    with pytest.raises(ValueError, match="unknown"):
        scenarios.names("no_such_tag")


def test_fast_scenarios_green_and_journal(tmp_path):
    journal = str(tmp_path / "journal.jsonl")
    res = scenarios.run_scenarios("fast", seed=0, journal=journal,
                                  echo=lambda s: None)
    assert len(res) >= 6
    bad = {r.name: [c.name for c in r.checks if not c.ok] + [r.error]
           for r in res if not r.ok}
    assert not bad, bad
    lines = [json.loads(ln) for ln in open(journal)]
    assert lines[-1]["summary"] is True
    assert lines[-1]["n_passed"] == len(res)
    assert {ln["scenario"] for ln in lines[:-1]} == {r.name for r in res}
    assert all(ln["checks"] for ln in lines[:-1])


def test_runner_reports_scenario_failure(tmp_path):
    @scenarios.scenario("_selftest")
    def failing_scenario(ctx):
        ctx.check("doomed", False, "by design")
    try:
        res = scenarios.run_scenarios("_selftest", seed=0,
                                      echo=lambda s: None)
        assert len(res) == 1 and not res[0].ok
        assert res[0].checks[0].detail == "by design"
    finally:
        del scenarios._REGISTRY["failing_scenario"]


def test_cli_list_and_exit_codes(capsys):
    assert chaos_main(["--scenarios", "fast", "--list"]) == 0
    out = capsys.readouterr().out
    assert "kill_mid_checkpoint_resume" in out


# --------------------------------------------------------------------------
# slow tier: subprocess hard-kill + real-model serve faults
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_env_kill_hard_exits_child():
    """REPRO_CHAOS_KILL arms an os._exit at the nth chaos-point hit --
    the SIGKILL stand-in for subprocess scenarios."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                       os.pardir, "src"))
    child = ("from repro.chaos.hooks import chaos_point\n"
             "chaos_point('p.x'); chaos_point('p.x'); print('alive')\n")
    env = dict(os.environ, PYTHONPATH=src, **hooks.kill_env("p.x", nth=2))
    p = subprocess.run([sys.executable, "-c", child], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == hooks.KILL_EXIT_CODE, (p.returncode, p.stderr)
    assert "alive" not in p.stdout


@pytest.mark.slow
def test_full_scenarios_green():
    res = scenarios.run_scenarios("subprocess,serve", seed=0,
                                  echo=lambda s: None)
    assert len(res) == 2
    bad = {r.name: [c.name for c in r.checks if not c.ok] + [r.error]
           for r in res if not r.ok}
    assert not bad, bad
