"""Outlier clamping & compensation: exactness, fidelity ordering (Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import occ, quantize


def _outlier_tensor(key, shape=(512, 256), outlier_frac=0.01, outlier_scale=50.0):
    """Normal body + channel-structured outliers (paper App. D)."""
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, shape)
    n_ch = max(1, int(shape[1] * outlier_frac))
    chans = jax.random.choice(k2, shape[1], (n_ch,), replace=False)
    boost = jnp.zeros(shape).at[:, chans].set(
        jax.random.normal(k3, (shape[0], n_ch)) * outlier_scale)
    return x + boost


def test_clamp_plus_residual_is_exact_identity():
    x = _outlier_tensor(jax.random.PRNGKey(0))
    xc, res = occ.clamp_and_residual(x, 0.99)
    # Unclamped elements are bitwise exact (res == 0); clamped elements
    # reconstruct to 1 ulp (hi + (x - hi) rounds once in f32).
    np.testing.assert_allclose(np.asarray(xc + res), np.asarray(x), rtol=1e-6)
    unclamped = np.asarray(res) == 0
    np.testing.assert_array_equal(np.asarray(xc)[unclamped],
                                  np.asarray(x)[unclamped])


def test_residual_sparsity_tracks_alpha():
    x = _outlier_tensor(jax.random.PRNGKey(1))
    for alpha, max_frac in [(0.999, 0.004), (0.99, 0.025), (0.97, 0.065)]:
        _, res = occ.clamp_and_residual(x, alpha)
        frac = float(jnp.mean(res != 0))
        # two-sided quantiles => ~2*(1-alpha) nonzeros (paper §3.2)
        assert frac <= max_frac, (alpha, frac)


def _heavy_tailed(key_int=0, shape=(512, 256)):
    """Student-t body + boosted channels: the paper's Fig. 11-13 regime."""
    rng = np.random.default_rng(key_int)
    x = jnp.asarray(rng.standard_t(3.0, size=shape), jnp.float32)
    ch = rng.choice(shape[1], max(1, shape[1] // 50), replace=False)
    return x.at[:, ch].mul(4.0)


def test_clamping_improves_quantization_fidelity_table1():
    """Paper Table 1 ordering under tensor-wise quantization (the regime of
    the paper's Fig. 4 'most values underflow to zero' analysis):
    no-clamp < clamp-only < clamp+comp, and alpha=0.99 > alpha=0.999."""
    x = _heavy_tailed(2)

    def fidelity(alpha=None, comp=False):
        if alpha is None:
            return occ.occ_metrics(x, quantize.fake_quant(x, axis=None))
        xc, res = occ.clamp_and_residual(x, alpha)
        xh = quantize.fake_quant(xc, axis=None)
        if comp:
            xh = xh + res
        return occ.occ_metrics(x, xh)

    base = fidelity()
    clamp = fidelity(alpha=0.999)
    comp999 = fidelity(alpha=0.999, comp=True)
    comp99 = fidelity(alpha=0.99, comp=True)
    assert float(clamp["snr"]) > float(base["snr"])
    assert float(comp999["snr"]) > float(clamp["snr"])
    assert float(comp99["snr"]) > float(comp999["snr"])  # smaller alpha wins
    assert float(comp999["sim"]) > float(clamp["sim"]) > float(base["sim"])


def test_vector_wise_plus_occ_beats_vector_wise_alone():
    """The full recipe (vector-wise + OCC) must beat vector-wise alone."""
    x = _heavy_tailed(3)
    base = occ.occ_metrics(x, quantize.fake_quant(x, axis=-1))
    xc, res = occ.clamp_and_residual(x, 0.99)
    comp = occ.occ_metrics(x, quantize.fake_quant(xc, axis=-1) + res)
    assert float(comp["snr"]) > float(base["snr"])


def test_sample_mode_close_to_exact():
    x = _outlier_tensor(jax.random.PRNGKey(3), shape=(1024, 512))
    lo_e, hi_e = occ.quantile_thresholds(x, 0.99, "exact")
    lo_s, hi_s = occ.quantile_thresholds(x, 0.99, "sample")
    scale = float(jnp.std(x))
    assert abs(float(hi_e - hi_s)) < 0.35 * scale
    assert abs(float(lo_e - lo_s)) < 0.35 * scale


def test_channel_compensation_captures_structured_outliers():
    x = _outlier_tensor(jax.random.PRNGKey(4), outlier_frac=0.02)
    _, res = occ.clamp_and_residual(x, 0.99)
    k = max(1, int(0.04 * x.shape[1]))
    idx, captured = occ.topk_outlier_channels(res, k)
    assert float(captured) > 0.85  # channel-structured => top-k captures most


def test_channel_compensation_matmul_close_to_dense():
    x = _outlier_tensor(jax.random.PRNGKey(5), outlier_frac=0.01)
    w = jax.random.normal(jax.random.PRNGKey(6), (x.shape[1], 128)) * 0.05
    _, res = occ.clamp_and_residual(x, 0.99)
    dense = res @ w
    skinny = occ.channel_compensation(res, w, max(1, int(0.04 * x.shape[1])))
    # skinny path should capture most of the compensation energy
    num = float(jnp.linalg.norm(dense - skinny))
    den = float(jnp.linalg.norm(dense) + 1e-9)
    assert num / den < 0.45
