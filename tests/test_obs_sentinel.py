"""Collapse sentinel: unit semantics (warmup / patience / re-arm) and the
end-to-end forced-collapse drill -- an injected outlier burst in an
embeddings-frontend smoke model trips the sentinel, which checkpoints and
flips the trainer to the bf16 fallback step (DESIGN.md §11c)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import get_policy
from repro.models import build_model
from repro.obs import (CollapseSentinel, SentinelConfig, read_jsonl)
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig

HEALTHY = {"agg/min_snr_db": 18.0, "agg/max_clamp_frac": 0.02,
           "agg/max_underflow_frac": 0.0, "agg/max_residual_mass": 0.05}
SICK = {"agg/min_snr_db": 2.0, "agg/max_clamp_frac": 0.02,
        "agg/max_underflow_frac": 0.0, "agg/max_residual_mass": 0.05}


# -------------------------------------------------------------------- units

def test_warmup_ignores_breaches():
    s = CollapseSentinel(SentinelConfig(patience=1, warmup_steps=3))
    for step in range(3):
        d = s.observe(step, SICK)
        assert not d.tripped and d.streak == 0
    assert s.observe(3, SICK).tripped


def test_patience_requires_consecutive_breaches():
    s = CollapseSentinel(SentinelConfig(patience=3, warmup_steps=0))
    assert not s.observe(0, SICK).tripped      # streak 1
    assert not s.observe(1, SICK).tripped      # streak 2
    d = s.observe(2, SICK)                     # streak 3 -> trip
    assert d.tripped and d.streak == 3
    assert "snr_db<6.0" in d.reasons[0]


def test_streak_resets_on_healthy_step():
    s = CollapseSentinel(SentinelConfig(patience=2, warmup_steps=0))
    assert not s.observe(0, SICK).tripped
    assert not s.observe(1, HEALTHY).tripped   # resets
    assert not s.observe(2, SICK).tripped      # streak back to 1
    assert s.observe(3, SICK).tripped


def test_rearm_after_trip():
    s = CollapseSentinel(SentinelConfig(patience=2, warmup_steps=0))
    assert not s.observe(0, SICK).tripped
    assert s.observe(1, SICK).tripped          # streak hit patience
    assert not s.observe(2, SICK).tripped      # re-armed: fresh streak of 1
    assert s.observe(3, SICK).tripped
    assert len(s.trips) == 2


def test_nonfinite_metric_is_breach():
    s = CollapseSentinel(SentinelConfig(patience=1, warmup_steps=0))
    d = s.observe(0, dict(HEALTHY, **{"agg/min_snr_db": float("nan")}))
    assert d.tripped and "nan" in d.reasons[0]


def test_missing_keys_are_not_breaches():
    s = CollapseSentinel(SentinelConfig(patience=1, warmup_steps=0))
    assert not s.observe(0, {}).tripped
    assert not s.observe(1, {"loss": 5.0}).tripped


def test_each_threshold_trips_alone():
    cfg = SentinelConfig(patience=1, warmup_steps=0)
    for key, bad in [("agg/min_snr_db", 1.0),
                     ("agg/max_clamp_frac", 0.9),
                     ("agg/max_underflow_frac", 0.5),
                     ("agg/max_residual_mass", 0.9)]:
        s = CollapseSentinel(cfg)
        d = s.observe(0, dict(HEALTHY, **{key: bad}))
        assert d.tripped, key
        assert len(d.reasons) == 1


def test_dge_threshold_optional():
    rec = dict(HEALTHY, **{"agg/max_dge_mismatch": 0.8})
    assert not CollapseSentinel(SentinelConfig(
        patience=1, warmup_steps=0)).observe(0, rec).tripped
    assert CollapseSentinel(SentinelConfig(
        patience=1, warmup_steps=0,
        max_dge_mismatch=0.5)).observe(0, rec).tripped


# -------------------------------------------------- end-to-end forced trip

CFG = get_config("llama2-400m", smoke=True).replace(frontend="embeddings")
SEQ, BATCH = 16, 2
BURST_FROM = 4


def _embed_batch(step: int, rng):
    """Healthy gaussian embeds; from BURST_FROM on, ~10% of the entries
    become heavy-tailed outliers (magnitudes 1e2..1e6) -- the §3.2 failure
    mode where the compensation path ends up carrying the signal."""
    x = rng.standard_normal((BATCH, SEQ, CFG.d_model)).astype(np.float32)
    if step >= BURST_FROM:
        mask = rng.random(x.shape) < 0.10
        mag = 10.0 ** rng.uniform(2, 6, size=x.shape)
        x = np.where(mask, np.sign(x) * mag, x).astype(np.float32)
    return {"embeds": jnp.asarray(x),
            "labels": jnp.asarray(
                rng.integers(0, CFG.vocab_size, (BATCH, SEQ)), jnp.int32)}


def test_outlier_burst_trips_sentinel_e2e(tmp_path):
    policy = get_policy("fp4_obs")
    model = build_model(CFG, policy)
    params, _ = model.init(jax.random.PRNGKey(0))
    adam_cfg = adam_mod.AdamConfig()
    state = {"params": params, "opt": adam_mod.init_state(params, adam_cfg),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(ts_mod.make_train_step(model, None, adam_cfg=adam_cfg,
                                             total_steps=10))
    fb_model = build_model(CFG, policy.fallback())
    fb_fn = jax.jit(ts_mod.make_train_step(fb_model, None, adam_cfg=adam_cfg,
                                           total_steps=10))
    rng = np.random.default_rng(0)
    log = str(tmp_path / "health.jsonl")
    trainer = Trainer(
        step_fn, state, batch_fn=lambda s: _embed_batch(s, rng),
        cfg=TrainerConfig(
            total_steps=10, ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=100,
            log_every=1, obs_jsonl=log,
            # residual-mass watch: healthy steps sit at ~0.07, the burst
            # at ~0.3 (validated margins); other thresholds at defaults
            sentinel=SentinelConfig(max_residual_mass=0.15, patience=2,
                                    warmup_steps=2)),
        fallback_step_fn=fb_fn)
    history = trainer.run(resume=False)

    # the sentinel tripped exactly once, two breaching steps into the burst
    trips = [h for h in history if h.get("event") == "collapse_trip"]
    assert len(trips) == 1
    assert trips[0]["step"] == BURST_FROM + 1
    assert any("residual_mass" in r for r in trips[0]["reasons"])
    # ... the tripped update was skipped (no loss record for that step) ...
    assert trips[0]["step"] not in {h["step"] for h in history if "loss" in h}
    # ... a checkpoint was cut on the way down ...
    assert os.path.isdir(str(tmp_path / "ckpt"))
    assert trainer.sentinel.trips and trainer.nan_skips == 1
    from repro.train import checkpoint as ckpt_mod
    assert ckpt_mod.latest_step(str(tmp_path / "ckpt")) is not None
    # ... the bf16 fallback took over and training completed
    assert [h["step"] for h in history if h.get("event") == "bf16_fallback"] \
        == [BURST_FROM + 1]
    assert trainer.fallback_active
    losses = [h for h in history if "loss" in h]
    assert losses[-1]["step"] == 9

    # JSONL: every pre-fallback step has the full per-layer health schema
    recs = [r for r in read_jsonl(log) if "event" not in r]
    pre = [r for r in recs if r["step"] <= BURST_FROM]
    assert len(pre) == BURST_FROM + 1
    for r in pre:
        for layer in range(CFG.n_layers):
            for gemm in ("wq", "wk", "wv", "wo", "wg", "wu", "wd"):
                assert f"L{layer}/{gemm}/clamp_frac" in r
                assert f"L{layer}/{gemm}/act/underflow_frac" in r
                assert f"L{layer}/{gemm}/act/snr_db" in r
                assert f"L{layer}/{gemm}/weight/dge_mismatch" in r
    # the burst is visible in the logged metric the sentinel watched
    by_step = {r["step"]: r for r in recs}
    assert by_step[BURST_FROM]["agg/max_residual_mass"] > 0.15
    assert by_step[0]["agg/max_residual_mass"] < 0.15
    # post-fallback steps log loss but no FP4 telemetry (bf16 path)
    post = [r for r in recs if r["step"] > BURST_FROM + 1]
    assert post and all("agg/max_residual_mass" not in r for r in post)


def test_fallback_policy_keeps_obs_flag():
    p = get_policy("fp4_obs")
    fb = p.fallback()
    assert fb.enabled is False and fb.obs_metrics is True
