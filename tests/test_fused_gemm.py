"""The `pallas_fused` backend (core/fp4_gemm.py + kernels/fp4_fused.py):
forward and gradient parity against the autodiff-composed `bf16_sim` path,
the custom-VJP wiring vs the paper's closed-form backward (Eq. 22), a
finite-difference spot check of the DGE soft-step the wgrad mask comes
from, and the fallback arms.

Tolerance notes. E2M1 grid values and their pairwise products are exact in
bf16, so the sim forward differs from the fused f32 accumulator only in
summation order -> forward parity is tight (~1e-5 relative). The composed
BACKWARD, however, multiplies cotangents through bf16 matmuls, so grad
parity carries the bf16 rounding of the cotangent chain -> rtol 2e-2
(same precedent as test_backward_matches_paper_eq22). The fused backward
vs the closed-form jnp backward is f32-vs-f32 and tight again.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dge, formats, quantize
from repro.core.fp4_gemm import fp4_matmul, fused_backend_eligible
from repro.core.linear import fp4_linear
from repro.core.policy import FP4_PAPER

KEY = jax.random.PRNGKey(42)

SIM = FP4_PAPER.replace(occ=False, compute="float32")
FUSED = SIM.replace(gemm_backend="pallas_fused")

# deliberately ragged: non-multiples of every default block size, K=129 odd
SHAPES = [(8, 16, 4), (37, 129, 19), (64, 64, 64), (3, 1, 2)]


def _rand(shape, key, scale=1.0):
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def _close(got, want, rtol, atol_rel=None):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    atol = (atol_rel if atol_rel is not None else rtol) * \
        (1.0 + (np.max(np.abs(want)) if want.size else 0.0))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


# --- forward parity --------------------------------------------------------

@pytest.mark.parametrize("mkn", SHAPES)
def test_forward_parity_vs_sim(mkn):
    M, K, N = mkn
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((M, K), k1), _rand((K, N), k2)
    _close(fp4_matmul(a, w, FUSED), fp4_matmul(a, w, SIM), rtol=2e-5)


def test_forward_parity_with_clamp_bounds():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((24, 48), k1, 2.0), _rand((48, 8), k2)
    bounds = (-1.23456, 0.98765)  # strictly between sample values: no ties
    _close(fp4_matmul(a, w, FUSED, clamp_bounds=bounds),
           fp4_matmul(a, w, SIM, clamp_bounds=bounds), rtol=2e-5)


def test_forward_parity_batched_3d():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((2, 9, 33), k1), _rand((33, 7), k2)
    yf, ys = fp4_matmul(a, w, FUSED), fp4_matmul(a, w, SIM)
    assert yf.shape == (2, 9, 7)
    _close(yf, ys, rtol=2e-5)


# --- gradient parity vs the autodiff-composed path -------------------------

def _grads(a, w, policy, clamp_bounds=None):
    weights = jnp.cos(jnp.arange(w.shape[-1]).astype(jnp.float32))

    def loss(a, w):
        return jnp.sum(fp4_matmul(a, w, policy,
                                  clamp_bounds=clamp_bounds) * weights)

    return jax.grad(loss, argnums=(0, 1))(a, w)


@pytest.mark.parametrize("mkn", SHAPES)
def test_grad_parity_vs_sim(mkn):
    M, K, N = mkn
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((M, K), k1), _rand((K, N), k2)
    da_f, dw_f = _grads(a, w, FUSED)
    da_s, dw_s = _grads(a, w, SIM)
    _close(da_f, da_s, rtol=2e-2)   # bf16 cotangent rounding in sim bwd
    _close(dw_f, dw_s, rtol=2e-2)


def test_grad_parity_with_clamp_bounds():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((24, 48), k1, 2.0), _rand((48, 8), k2)
    bounds = (-1.23456, 0.98765)  # off any sample value: clip subgradient
    # ties (where fused's indicator mask deviates) cannot trigger
    da_f, dw_f = _grads(a, w, FUSED, clamp_bounds=bounds)
    da_s, dw_s = _grads(a, w, SIM, clamp_bounds=bounds)
    _close(da_f, da_s, rtol=2e-2)
    _close(dw_f, dw_s, rtol=2e-2)
    # entries clamped away must carry exactly zero activation gradient
    dead = (np.asarray(a) < bounds[0]) | (np.asarray(a) > bounds[1])
    assert dead.any()
    assert np.all(np.asarray(da_f)[dead] == 0.0)


def test_grad_parity_batched_3d():
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((2, 9, 33), k1), _rand((33, 7), k2)
    da_f, dw_f = _grads(a, w, FUSED)
    da_s, dw_s = _grads(a, w, SIM)
    assert da_f.shape == a.shape and dw_f.shape == w.shape
    _close(da_f, da_s, rtol=2e-2)
    _close(dw_f, dw_s, rtol=2e-2)


def test_fused_backward_matches_paper_eq22_closed_form():
    """f32-vs-f32: the custom VJP against the closed-form backward."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1), _rand((32, 8), k2)
    y, vjp = jax.vjp(lambda a, w: fp4_matmul(a, w, FUSED), a, w)
    g = jnp.ones_like(y)
    da, dw = vjp(g)
    sa = quantize.absmax_scale(a, -1, 6.0)
    sw = quantize.absmax_scale(w, 0, 6.0)
    a_dq = quantize.lut_round(a * sa) / sa
    w_dq = quantize.lut_round(w * sw) / sw
    want_da = g @ w_dq.T
    want_dw = (a_dq.T @ g) * dge.dge_derivative(w * sw, k=5.0, clip=3.0)
    _close(da, want_da, rtol=1e-4)
    _close(dw, want_dw, rtol=1e-4)


# --- DGE finite-difference spot check --------------------------------------

def test_dge_derivative_matches_soft_step_finite_difference():
    """dge_derivative is the analytic derivative of the power-law soft step
        f(x) = lo + delta * 0.5*(1 + sign(2t-1)*|2t-1|^(1/k)),  t=(x-lo)/delta
    inside each quantization interval. Central-difference the soft step in
    float64 at interior points (away from t=1/2 and the clip plateau) and
    compare.
    """
    k = 5.0
    los, deltas = (np.asarray(v, np.float64)
                   for v in formats.intervals(formats.E2M1))
    xs, want = [], []
    for lo, delta in zip(los, deltas):
        for t in (0.11, 0.27, 0.73, 0.9):
            xs.append(lo + t * delta)
            want.append((1.0 / k) * abs(2.0 * t - 1.0) ** (1.0 / k - 1.0))
    xs, want = np.asarray(xs), np.asarray(want)
    assert np.all(want < 3.0 * 0.9)  # interior points: clip never binds

    def soft(x):
        i = np.clip(np.searchsorted(los, x, side="right") - 1, 0,
                    len(los) - 1)
        t = (x - los[i]) / deltas[i]
        return los[i] + deltas[i] * 0.5 * (
            1.0 + np.sign(2 * t - 1) * np.abs(2 * t - 1) ** (1.0 / k))

    h = 1e-7
    fd = (soft(xs + h) - soft(xs - h)) / (2 * h)
    np.testing.assert_allclose(fd, want, rtol=1e-4)

    got = np.asarray(dge.dge_derivative(jnp.asarray(xs, jnp.float32),
                                        k=k, clip=3.0))
    np.testing.assert_allclose(got, fd, rtol=1e-3)


def test_fused_wgrad_carries_dge_mask():
    """Chain the FD-validated derivative through the fused dW: the custom
    VJP's weight gradient must be elementwise proportional to f'(w*sw)."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1), _rand((32, 8), k2)
    dw = jax.grad(lambda w: jnp.sum(fp4_matmul(a, w, FUSED)))(w)
    sa = quantize.absmax_scale(a, -1, 6.0)
    sw = quantize.absmax_scale(w, 0, 6.0)
    mask = np.asarray(dge.dge_derivative(w * sw, k=5.0, clip=3.0))
    raw = np.asarray((quantize.lut_round(a * sa) / sa).T
                     @ jnp.ones((16, 8), jnp.float32))
    np.testing.assert_allclose(np.asarray(dw), raw * mask,
                               rtol=1e-4, atol=1e-4 * np.abs(raw).max())


# --- fallback arms ---------------------------------------------------------

def test_fused_backend_eligibility_table():
    assert fused_backend_eligible(FUSED)
    assert fused_backend_eligible(FUSED.replace(w_quant="ste"))
    assert not fused_backend_eligible(SIM)                       # bf16_sim
    assert not fused_backend_eligible(FUSED.replace(w_quant="none"))
    assert not fused_backend_eligible(FUSED.replace(a_quant="none"))
    assert not fused_backend_eligible(FUSED.replace(a_axis=None,
                                                    w_axis=None))


@pytest.mark.parametrize("kw", [
    dict(w_quant="none"),                 # W8A4-style arm
    dict(a_quant="none"),                 # W4A8-style arm
    dict(a_axis=None, w_axis=None),       # tensor-wise granularity
])
def test_fallback_arms_bitwise_match_sim(kw):
    """Ineligible pallas_fused policies must take the EXACT composed code
    path bf16_sim takes -- bitwise, not just close."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1), _rand((32, 8), k2)
    y_f = fp4_matmul(a, w, FUSED.replace(**kw))
    y_s = fp4_matmul(a, w, SIM.replace(**kw))
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_s))


# --- fp4_linear OCC arms on the fused backend ------------------------------

@pytest.mark.parametrize("comp", ["dense", "channel", "none"])
def test_linear_occ_arms_forward_parity(comp):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = _rand((32, 64), k1)
    a = a.at[:, 3].mul(80.0)  # channel outlier: the clamp must bind
    w = _rand((64, 16), k2, 0.1)
    b = _rand((16,), k3)
    pol_f = FP4_PAPER.replace(occ_comp=comp, occ_threshold="exact",
                              compute="float32",
                              gemm_backend="pallas_fused")
    pol_s = pol_f.replace(gemm_backend="bf16_sim")
    _close(fp4_linear(a, w, b, policy=pol_f),
           fp4_linear(a, w, b, policy=pol_s), rtol=1e-4)


def test_linear_occ_clamp_only_arm_grad_flows():
    """occ_comp="none" + fused backend is the in-kernel-clamp arm
    (core/linear.py); gradients must be finite and nonzero."""
    k1, k2 = jax.random.split(KEY)
    a, w = _rand((16, 32), k1), _rand((32, 8), k2)
    pol = FP4_PAPER.replace(occ_comp="none", occ_threshold="exact",
                            compute="float32",
                            gemm_backend="pallas_fused")
    da, dw = jax.grad(lambda a, w: jnp.sum(fp4_linear(a, w, policy=pol)),
                      argnums=(0, 1))(a, w)
    assert np.all(np.isfinite(np.asarray(da)))
    assert np.all(np.isfinite(np.asarray(dw)))
    assert float(jnp.linalg.norm(dw)) > 0
