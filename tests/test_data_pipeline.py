"""repro.data v2 pipeline tests (DESIGN.md §14, docs/data_format.md):

  * shard writer/reader roundtrip: tokens survive byte-exactly, dtype
    selection tracks vocab size, manifest is the atomic commit point
  * packing invariants: fixed shapes, pad conventions, loss-mask rule,
    per-fragment position restart
  * the headline resume guarantee -- kill a PackedStream mid-shard,
    restore from its state_dict, and the next 100 batches are
    token-identical to an uninterrupted run
  * DevicePrefetcher: batch-for-batch equivalence with the blocking
    stream, consumed-state (not read-ahead) checkpointing, restart,
    producer-error surfacing
  * Trainer integration: interrupted+resumed training consumes the
    exact token stream of an uninterrupted run, and data/* health keys
    ride the obs JSONL sink
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.data import (DataConfig, DevicePrefetcher, PackedStream,
                        ShardReader, ShardWriter, SyntheticLM,
                        SyntheticStream, make_batch_fn, packing,
                        synthetic_documents, token_dtype,
                        write_synthetic_shards)


def _write_corpus(tmp_path, n_docs=60, vocab=4096, seed=0,
                  shard_tokens=4096):
    cfg = DataConfig(vocab_size=vocab, seq_len=128, global_batch=4,
                     seed=seed)
    root = os.path.join(str(tmp_path), "corpus")
    manifest = write_synthetic_shards(root, cfg, n_docs,
                                      shard_tokens=shard_tokens)
    return manifest, cfg


# ---------------------------------------------------------------- shards
def test_token_dtype_tracks_vocab():
    assert token_dtype(32000) == np.uint16
    assert token_dtype(65536) == np.uint16
    assert token_dtype(65537) == np.uint32


def test_shard_roundtrip_byte_exact(tmp_path):
    docs = [np.arange(n, dtype=np.int64) % 500 for n in (3, 70, 1, 41, 9)]
    w = ShardWriter(str(tmp_path / "c"), vocab_size=500, shard_tokens=64)
    for d in docs:
        w.add_document(d)
    manifest = w.finalize({"note": "test"})
    r = ShardReader(manifest)
    assert r.total_docs == len(docs)
    assert r.total_tokens == sum(len(d) for d in docs)
    assert len(r.shards) > 1            # 64-token shards forced a roll
    for i, d in enumerate(docs):
        np.testing.assert_array_equal(np.asarray(r.doc(i), np.int64), d)
        assert r.doc_len(i) == len(d)


def test_manifest_is_commit_point(tmp_path):
    w = ShardWriter(str(tmp_path / "c"), vocab_size=100, shard_tokens=1024)
    w.add_document(np.arange(10))
    # before finalize there is no manifest -> readers refuse the dir
    with pytest.raises((FileNotFoundError, OSError)):
        ShardReader(os.path.join(str(tmp_path / "c"), "manifest.json"))
    manifest = w.finalize()
    assert os.path.basename(manifest) == "manifest.json"
    meta = json.load(open(manifest))
    assert meta["format"] == "repro-shards-v1" and meta["total_docs"] == 1


def test_synthetic_documents_deterministic():
    import dataclasses
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=2, seed=7)
    a = list(synthetic_documents(cfg, 12))
    b = list(synthetic_documents(cfg, 12))
    assert len(a) == 12
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = list(synthetic_documents(dataclasses.replace(cfg, seed=8), 12))
    assert any(x.shape != y.shape or not np.array_equal(x, y)
               for x, y in zip(a, c))


# --------------------------------------------------------------- packing
def test_split_spans_covers_document():
    assert packing.split_spans(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert packing.split_spans(4, 4) == [(0, 4)]
    assert packing.split_spans(0, 4) == []


def test_best_fit_prefers_tightest_row():
    # frag of len 3 fits rows with free 3 (exact) and 5; exact wins
    assert packing.best_fit([3], [5, 3]) == (0, 1)
    # nothing fits -> None
    assert packing.best_fit([9], [5, 3]) is None
    # tie on leftover -> earliest fragment, then lowest row
    assert packing.best_fit([2, 2], [2, 2]) == (0, 0)


def test_assemble_conventions():
    rows = [[np.array([5, 6, 7]), np.array([8, 9])], [np.array([1])]]
    pb = packing.assemble(rows, seq_len=6)
    t, seg = pb.arrays["tokens"], pb.arrays["segment_ids"]
    pos, lm = pb.arrays["positions"], pb.arrays["loss_mask"]
    np.testing.assert_array_equal(t[0], [5, 6, 7, 8, 9, 0])
    np.testing.assert_array_equal(seg[0], [1, 1, 1, 2, 2, 0])
    np.testing.assert_array_equal(pos[0], [0, 1, 2, 0, 1, -1])
    # loss only where the predecessor is the same segment
    np.testing.assert_array_equal(lm[0], [0, 1, 1, 0, 1, 0])
    np.testing.assert_array_equal(seg[1], [1, 0, 0, 0, 0, 0])
    assert pb.meta["n_fragments"] == 3
    assert pb.meta["n_pad_tokens"] == 6
    assert pb.meta["pack_frac"] == pytest.approx(6 / 12)


# ------------------------------------------------------ resume guarantee
def test_stream_resume_bit_exact_100_batches(tmp_path):
    """The headline guarantee: kill mid-shard, restore, next 100 batches
    token-identical to the uninterrupted run."""
    manifest, _ = _write_corpus(tmp_path, n_docs=40, shard_tokens=2048)

    def mk():
        return PackedStream(ShardReader(manifest), seq_len=96,
                            batch_size=3, seed=11, lookahead=6)

    ref = mk()
    for _ in range(7):                      # advance into the corpus
        ref.next_batch()
    snap = ref.state_dict()
    json.dumps(snap)                        # must be JSON-serializable
    expect = [ref.next_batch() for _ in range(100)]

    resumed = mk()                          # fresh process simulation
    resumed.load_state_dict(json.loads(json.dumps(snap)))
    for i, want in enumerate(expect):
        got = resumed.next_batch()
        for k in want.arrays:
            np.testing.assert_array_equal(
                got.arrays[k], want.arrays[k],
                err_msg=f"batch {i} key {k} diverged after resume")


def test_stream_state_rejects_mismatch(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=10)
    s = PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                     seed=3)
    st = s.state_dict()
    with pytest.raises(ValueError, match="seed mismatch"):
        PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                     seed=4).load_state_dict(st)
    with pytest.raises(ValueError, match="version"):
        s.load_state_dict({**st, "version": 99})


def test_stream_epochs_wrap_and_reshuffle(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=6, shard_tokens=2048)
    s = PackedStream(ShardReader(manifest), seq_len=128, batch_size=4,
                     seed=0)
    seen_epochs = set()
    for _ in range(30):
        s.next_batch()
        seen_epochs.add(s.state_dict()["epoch"])
    assert len(seen_epochs) > 1             # tiny corpus must wrap


def test_synthetic_stream_matches_batch_fn():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=2, seed=5)
    stream = SyntheticStream(SyntheticLM(cfg))
    batch_fn = make_batch_fn(cfg)
    for step in range(4):
        pb = stream.next_batch()
        np.testing.assert_array_equal(pb.arrays["tokens"], batch_fn(step))
    st = stream.state_dict()
    stream.next_batch()
    stream.load_state_dict(st)
    np.testing.assert_array_equal(stream.next_batch().arrays["tokens"],
                                  batch_fn(4))


# ------------------------------------------------------------- prefetch
def test_prefetcher_matches_blocking_stream(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=30)
    ref = PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                       seed=1)
    pf = DevicePrefetcher(
        PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                     seed=1), depth=3)
    try:
        for _ in range(25):
            want, got = ref.next_batch(), pf.next_batch()
            for k in want.arrays:
                np.testing.assert_array_equal(got.arrays[k],
                                              want.arrays[k])
    finally:
        pf.stop()


def test_prefetcher_reports_consumed_state(tmp_path):
    """state_dict() must describe the *consumed* cursor, never the
    producer's read-ahead position: save -> restore -> next must equal
    the uninterrupted sequence."""
    manifest, _ = _write_corpus(tmp_path, n_docs=30)

    def mk():
        return DevicePrefetcher(
            PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                         seed=2), depth=3)

    pf = mk()
    try:
        for _ in range(5):
            pf.next_batch()
        snap = pf.state_dict()
        expect = [pf.next_batch() for _ in range(20)]
    finally:
        pf.stop()

    pf2 = mk()
    try:
        pf2.load_state_dict(json.loads(json.dumps(snap)))
        for i, want in enumerate(expect):
            got = pf2.next_batch()
            for k in want.arrays:
                np.testing.assert_array_equal(
                    got.arrays[k], want.arrays[k],
                    err_msg=f"post-restore batch {i} key {k}")
    finally:
        pf2.stop()


def test_prefetcher_place_fn_and_stats(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=10)
    calls = []

    def place(arrays):
        calls.append(sorted(arrays))
        return {k: v + 0 for k, v in arrays.items()}

    pf = DevicePrefetcher(
        PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                     seed=0), place_fn=place, depth=2)
    try:
        for _ in range(4):
            pf.next_batch()
        stats = pf.stats()
    finally:
        pf.stop()
    assert calls and "tokens" in calls[0]
    assert set(stats) == {"stall_ms", "queue_depth", "pack_frac"}
    assert 0.0 < stats["pack_frac"] <= 1.0
    # stats() drains: an immediate second call averages over nothing new
    assert pf.stats()["pack_frac"] == 0.0


def test_prefetcher_surfaces_producer_error():
    class Boom:
        def state_dict(self):
            return {}

        def load_state_dict(self, s):
            pass

        def next_batch(self):
            raise RuntimeError("shard corrupted")

    pf = DevicePrefetcher(Boom(), depth=1)
    try:
        with pytest.raises(RuntimeError, match="producer died"):
            pf.next_batch()
    finally:
        pf.stop()


def test_prefetcher_stop_joins_thread(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=10)
    pf = DevicePrefetcher(
        PackedStream(ShardReader(manifest), seq_len=64, batch_size=2,
                     seed=0), depth=2)
    pf.next_batch()
    before = threading.active_count()
    pf.stop()
    pf.stop()                               # idempotent
    assert threading.active_count() <= before


# ------------------------------------------------------------- trainer
def _tiny_trainer(loader, ckpt_dir, total_steps, record):
    from repro.train.trainer import Trainer, TrainerConfig

    def step_fn(state, batch):
        record.append(np.asarray(batch["tokens"]).copy())
        return ({"step": state["step"] + 1},
                {"loss": np.float32(1.0)})

    return Trainer(step_fn, {"step": np.int32(0)}, loader=loader,
                   cfg=TrainerConfig(total_steps=total_steps,
                                     ckpt_dir=ckpt_dir, ckpt_every=4,
                                     log_every=100))


def test_trainer_loader_resume_token_identical(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=40)

    def mk_loader():
        return PackedStream(ShardReader(manifest), seq_len=64,
                            batch_size=2, seed=9)

    # uninterrupted reference run
    ref_batches = []
    _tiny_trainer(mk_loader(), str(tmp_path / "ck_ref"), 12,
                  ref_batches).run()

    # interrupted at step 7 (mid-interval: last checkpoint at step 4)
    part = []
    _tiny_trainer(mk_loader(), str(tmp_path / "ck"), 7, part).run()
    resumed = []
    _tiny_trainer(mk_loader(), str(tmp_path / "ck"), 12, resumed).run()

    # run() checkpoints at exit, so the resumed run replays nothing and
    # the concatenation equals the uninterrupted stream token-for-token
    full = part + resumed
    assert len(full) == len(ref_batches) == 12
    for i, (a, b) in enumerate(zip(full, ref_batches)):
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"trainer batch {i}")


def test_trainer_requires_exactly_one_source():
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = TrainerConfig(total_steps=1)
    with pytest.raises(ValueError, match="exactly one"):
        Trainer(lambda s, b: (s, {}), {}, cfg=cfg)
    with pytest.raises(ValueError, match="exactly one"):
        Trainer(lambda s, b: (s, {}), {}, batch_fn=lambda i: {},
                loader=object(), cfg=cfg)


def test_trainer_obs_jsonl_carries_data_keys(tmp_path):
    manifest, _ = _write_corpus(tmp_path, n_docs=20)
    loader = PackedStream(ShardReader(manifest), seq_len=64,
                          batch_size=2, seed=0)
    log = tmp_path / "obs.jsonl"
    from repro.train.trainer import Trainer, TrainerConfig

    def step_fn(state, batch):
        return {"step": state["step"] + 1}, {"loss": np.float32(0.5)}

    Trainer(step_fn, {"step": np.int32(0)}, loader=loader,
            cfg=TrainerConfig(total_steps=3, obs_jsonl=str(log),
                              log_every=100)).run()
    recs = [json.loads(l) for l in open(log)]
    assert len(recs) == 3
    for r in recs:
        assert {"data/stall_ms", "data/queue_depth",
                "data/pack_frac"} <= set(r)
