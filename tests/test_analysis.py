"""Analysis-layer unit tests: HLO collective parsing and the FLOP model."""
import numpy as np
import pytest

from repro.analysis import flops as flops_mod
from repro.analysis import hlo as hlo_mod
from repro.analysis.roofline import Roofline, roofline_terms
from repro.configs import SHAPES, get_config

HLO_SAMPLE = """
HloModule jit_step, entry_computation_layout={()->f32[]}

%cond.1 (arg.1: (s32[], f32[2,4])) -> pred[] {
  %arg.1 = (s32[], f32[2,4]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%arg.1), index=0
  %constant.5 = s32[] constant(12)
  ROOT %compare.1 = pred[] compare(%gte.1, %constant.5), direction=LT
}

%body.1 (arg.2: (s32[], f32[2,4])) -> (s32[], f32[2,4]) {
  %arg.2 = (s32[], f32[2,4]) parameter(0)
  %gte.2 = f32[2,4]{1,0} get-tuple-element(%arg.2), index=1
  %ar.1 = f32[2,4]{1,0} all-reduce(%gte.2), replica_groups={{0,1,2,3}}, to_apply=%sum
  %gte.3 = s32[] get-tuple-element(%arg.2), index=0
  ROOT %tuple.1 = (s32[], f32[2,4]) tuple(%gte.3, %ar.1)
}

ENTRY %main () -> f32[] {
  %init = (s32[], f32[2,4]) tuple(...)
  %while.1 = (s32[], f32[2,4]) while(%init), condition=%cond.1, body=%body.1
  %ag.1 = bf16[8,16]{1,0} all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  %cp.1 = f32[4,4]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %out = f32[] constant(0)
}
"""


def test_collective_parser_ops_and_trip_counts():
    res = hlo_mod.collective_bytes(HLO_SAMPLE)
    assert res["count"] == 3
    # while body all-reduce multiplied by trip count 12
    ar_payload = 2 * 4 * 4                      # f32[2,4]
    ar_wire = ar_payload * 2 * 3 / 4            # ring, n=4
    assert res["by_op"]["all-reduce"] == pytest.approx(ar_wire * 12)
    ag_payload = 8 * 16 * 2                     # bf16[8,16]
    ag_wire = ag_payload * 7 / 8                # iota groups size 8
    assert res["by_op"]["all-gather"] == pytest.approx(ag_wire)
    cp_wire = 4 * 4 * 4
    assert res["by_op"]["collective-permute"] == pytest.approx(cp_wire)
    assert res["multiplied_entries"] == 1


def test_flop_model_scales_like_6nd():
    """Dense train FLOPs should be ~6*N*D for big seq-independent models."""
    cfg = get_config("qwen1.5-32b")
    shape = SHAPES["train_4k"]
    out = flops_mod.model_flops(cfg, shape, "train")
    # param count of the GeMM weights (per-token linear flops / 2 * ... )
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    n_matmul = L * (2 * d * d * 2 + 3 * d * f) + d * cfg.vocab_size
    tokens = shape.global_batch * shape.seq_len
    expected = 6.0 * n_matmul * tokens
    assert out["model_flops"] == pytest.approx(expected, rel=0.15)


def test_flop_model_moe_counts_active_params_only():
    cfg = get_config("qwen3-moe-30b-a3b")
    shape = SHAPES["train_4k"]
    out = flops_mod.model_flops(cfg, shape, "train")
    dense_equiv = flops_mod.model_flops(
        cfg.replace(n_experts=0, top_k=0,
                    d_ff=cfg.moe_d_ff * cfg.top_k), shape, "train")
    # top-8-of-128 experts ~= dense with 8x expert width (+ router overhead)
    assert out["model_flops"] == pytest.approx(dense_equiv["model_flops"],
                                               rel=0.1)


def test_decode_flops_linear_in_cache():
    cfg = get_config("gemma2-9b")
    s32 = flops_mod.model_flops(cfg, SHAPES["decode_32k"], "decode")
    # per-token work must be dominated by parameter reads, not S^2
    per_tok = s32["model_flops"] / s32["tokens"]
    assert per_tok < 1e12  # ~2*9B + attention term


def test_roofline_dominant_term():
    r = Roofline(compute_bf16_s=1.0, compute_fp4_s=0.6, memory_s=2.0,
                 collective_s=0.5)
    assert r.dominant == "memory"
    assert r.step_time_s == 2.0


def test_scan_corrections_present_for_ssm_and_rwkv():
    cfg = get_config("zamba2-7b")
    out = flops_mod.model_flops(cfg, SHAPES["train_4k"], "train")
    names = [s.name for s in out["scan_corrections"]]
    assert "ssd_chunks" in names
    cfg = get_config("rwkv6-1.6b")
    out = flops_mod.model_flops(cfg, SHAPES["train_4k"], "train")
    assert "wkv_steps" in [s.name for s in out["scan_corrections"]]
