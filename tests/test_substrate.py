"""Substrate tests: optimizer, schedule, data determinism, checkpointing,
trainer fault tolerance, elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.optim import adam as adam_mod
from repro.optim.schedule import warmup_cosine
from repro.train import checkpoint as ckpt_mod


# --------------------------------------------------------------------- adam

def _quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray([[0.5, -0.5]])}


def test_adam_converges_on_quadratic():
    cfg = adam_mod.AdamConfig(weight_decay=0.0)
    params = _quad_params()
    state = adam_mod.init_state(params, cfg)
    loss = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state = adam_mod.apply_update(params, grads, state, 0.05, cfg)
    assert float(loss(params)) < 1e-3


def test_adam_fp8_moments_are_fp8():
    cfg = adam_mod.AdamConfig()
    params = _quad_params()
    state = adam_mod.init_state(params, cfg)
    m = state["per_param"]["w"]["m"]
    assert isinstance(m, adam_mod.MomentFP8)
    assert m.q.dtype == jnp.float8_e4m3fn
    assert state["per_param"]["w"]["v"].dtype == jnp.float16


def test_adam_fp8_tracks_fp32_closely():
    """The mixed-precision recipe must track full-precision Adam."""
    k = jax.random.PRNGKey(0)
    w0 = jax.random.normal(k, (64,))
    tgt = jax.random.normal(jax.random.PRNGKey(1), (64,))
    loss = lambda p: jnp.sum((p["w"] - tgt) ** 2)

    def train(m_dtype, v_dtype):
        cfg = adam_mod.AdamConfig(weight_decay=0.0, m_dtype=m_dtype,
                                  v_dtype=v_dtype)
        params = {"w": w0}
        state = adam_mod.init_state(params, cfg)
        for _ in range(100):
            grads = jax.grad(loss)(params)
            params, state = adam_mod.apply_update(params, grads, state,
                                                  0.02, cfg)
        return float(loss(params))

    l_fp8 = train("float8_e4m3fn", "float16")
    l_f32 = train("float32", "float32")
    # Both arms must converge on the quadratic; mid-trajectory losses are
    # noisy, so assert convergence rather than trajectory identity.
    init = float(loss({"w": w0}))
    assert l_f32 < 0.2 * init
    assert l_fp8 < 0.3 * init


def test_grad_clipping():
    grads = {"w": jnp.full((4,), 100.0)}
    clipped, norm = adam_mod.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adam_mod.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_schedule_shape():
    total = 1000
    lrs = [float(warmup_cosine(s, total_steps=total, peak_lr=3e-4))
           for s in range(0, total + 1, 50)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(3e-4, rel=0.05)
    assert lrs[-1] == pytest.approx(3e-5, rel=0.05)  # 10% of peak


# --------------------------------------------------------------------- data

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=7)
    ds = SyntheticLM(cfg)
    a = ds.batch(step=3, shard=0, n_shards=2)
    b = ds.batch(step=3, shard=0, n_shards=2)
    np.testing.assert_array_equal(a, b)           # deterministic
    c = ds.batch(step=3, shard=1, n_shards=2)
    assert not np.array_equal(a, c)               # disjoint shards
    d = ds.batch(step=4, shard=0, n_shards=2)
    assert not np.array_equal(a, d)               # advances with step
    assert a.shape == (4, 32) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 512


def test_data_is_learnable():
    """Bigram structure => conditional entropy < unigram entropy."""
    cfg = DataConfig(vocab_size=128, seq_len=256, global_batch=16)
    ds = SyntheticLM(cfg)
    toks = ds.global_batch(0)
    # empirical check: P(next == markov_next | prev) ~ 0.75 >> 1/V
    prev = toks[:, :-1]
    nxt = toks[:, 1:]
    markov_next = (prev + ds._state_shift[ds._tok_state[prev]]) % cfg.vocab_size
    agreement = (nxt == markov_next).mean()
    assert agreement > 0.5


# --------------------------------------------------------------- checkpoint

def _tiny_state():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3)},
        "opt": {"m": adam_mod.MomentFP8(
            jnp.asarray([1.0, 2.0], jnp.float8_e4m3fn),
            jnp.asarray(1.0))},
        "step": jnp.asarray(5, jnp.int32),
    }


def test_checkpoint_roundtrip_bitexact(tmp_path):
    state = _tiny_state()
    ckpt_mod.save(str(tmp_path), 5, state)
    restored, manifest = ckpt_mod.restore(str(tmp_path), state)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    state = _tiny_state()
    ckpt_mod.save(str(tmp_path), 1, state)
    ckpt_mod.save(str(tmp_path), 2, state)
    entries = os.listdir(tmp_path)
    assert sorted(entries) == ["step_00000001", "step_00000002"]
    assert not any(e.endswith(".tmp") for e in entries)


def test_checkpoint_retention(tmp_path):
    state = _tiny_state()
    for s in range(5):
        ckpt_mod.save(str(tmp_path), s, state)
    ckpt_mod.keep_last(str(tmp_path), 2)
    assert ckpt_mod.latest_step(str(tmp_path)) == 4
    assert len(os.listdir(tmp_path)) == 2


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ckpt_mod.save(str(tmp_path), 1, _tiny_state())
    bad = {"params": {"w": jnp.zeros((2, 3), jnp.bfloat16)}}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt_mod.restore(str(tmp_path), bad)
