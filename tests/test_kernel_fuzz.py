"""Differential kernel-fuzz harness: every Pallas kernel vs its pure-jnp
oracle (kernels/ref.py) in interpret mode.

Two layers of coverage:
  * a deterministic adversarial corpus (all-zeros, f32 denormals, rows
    pinned to exact round-to-nearest tie points, 1e30-magnitude rows,
    outlier-heavy mixes) crossed with ragged shapes -- odd M/K/N,
    non-block-multiples, K=1 -- and deliberately tiny block sizes so every
    kernel exercises its tail-masking paths;
  * hypothesis-driven random sweeps (the deterministic shim in
    tests/_hypothesis_shim.py when hypothesis isn't installed).

Tolerances are stored per kernel in TOLERANCES. The quantizer-family
kernels must match their oracles bit-exactly (same threshold chain, same
underflow floor); the GEMM-family kernels accumulate per-K-block so only
the f32 summation ORDER differs from the one-shot oracle matmul -- their
atol is scaled by (1 + max|oracle|) to stay meaningful across the 1e-30
.. 1e30 dynamic range of the corpus.

Everything is seeded; the suite is fully deterministic run-to-run.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:                                        # pragma: no cover
    from _hypothesis_shim import given, settings, st, hnp

from repro.core import quantize
from repro.kernels import ops, ref

SEED = 0xF4F4

# --- stored per-kernel tolerances ------------------------------------------
# rtol/atol feed np.testing.assert_allclose; atol is multiplied by
# (1 + max|oracle|) so it tracks the output's scale (pure-relative kernels
# keep atol=0). Exactness claims are load-bearing: the kernels reimplement
# the reference math (threshold chain, absmax floor) rather than
# approximating it, and this table is where that contract is pinned.
TOLERANCES = {
    "fp4_quant":       dict(rtol=0.0, atol=0.0),      # identical chain
    "fused_row_scale": dict(rtol=0.0, atol=0.0),      # identical floor/max
    "outlier_clamp":   dict(rtol=0.0, atol=0.0),      # pure clamp
    "fp4_matmul":      dict(rtol=1e-5, atol=1e-6),    # K-blocked f32 sums
    "fused_fwd":       dict(rtol=1e-5, atol=1e-6),
    "fused_dgrad":     dict(rtol=1e-5, atol=1e-6),
    "fused_wgrad":     dict(rtol=1e-5, atol=1e-6),
    "flash_attention": dict(rtol=1e-4, atol=1e-5),    # online vs 2-pass softmax
}


def assert_close(name: str, got, want):
    t = TOLERANCES[name]
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    scale = 1.0 + (float(np.max(np.abs(want))) if want.size else 0.0)
    np.testing.assert_allclose(got, want, rtol=t["rtol"],
                               atol=t["atol"] * scale,
                               err_msg=f"kernel {name!r} diverged from oracle")


# --- adversarial corpus ----------------------------------------------------

_TIE_POINTS = np.array([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0], np.float32)


def _corpus(shape: tuple[int, int], rng: np.random.Generator):
    """Yield (tag, (M,K) f32 array) adversarial cases for one shape."""
    normal = rng.standard_normal(shape).astype(np.float32)
    yield "normal", normal
    yield "zeros", np.zeros(shape, np.float32)
    # f32 denormals: below the 1e-30 absmax floor, so scale must snap to 1
    # and everything quantizes to 0 (not inf/0*inf garbage).
    yield "denormal", np.float32(1e-39) * np.sign(normal + np.float32(0.25))
    # rows whose absmax is EXACTLY max_value -> scale is exactly 1, and the
    # remaining entries sit on round-to-nearest tie points: both sides must
    # break ties identically (toward +inf, searchsorted side="right").
    ties = rng.choice(_TIE_POINTS, size=shape).astype(np.float32)
    ties *= np.where(rng.random(shape) < 0.5, -1.0, 1.0).astype(np.float32)
    ties[..., -1] = np.float32(6.0)
    yield "ties", ties
    yield "huge", normal * np.float32(1e30)
    # outlier-heavy: unit-scale body with a sparse 1e3 spike population --
    # the regime OCC clamping targets (post-clamp outliers when lohi is
    # finite, scale-blowup stress when it isn't).
    outl = normal.copy()
    spikes = rng.random(shape) < 0.05
    outl[spikes] = (1e3 * np.sign(outl)[spikes]).astype(np.float32)
    yield "outliers", outl


_QUANT_SHAPES = [(1, 1), (1, 7), (3, 1), (37, 65), (64, 128), (130, 257)]
_MNK_SHAPES = [(1, 1, 1), (7, 1, 5), (7, 3, 5), (37, 129, 19), (64, 64, 64),
               (65, 33, 130)]
_LOHI_CASES = [None, (-2.5, 2.5)]


def _lohi_arr(lohi):
    if lohi is None:
        return jnp.asarray([[-jnp.inf, jnp.inf]], jnp.float32)
    return jnp.asarray([list(lohi)], jnp.float32)


def _grid_weights(K: int, N: int, rng: np.random.Generator):
    """(w_q on-grid, sw (1,N)) from a random bf16-ish weight."""
    w = rng.standard_normal((K, N)).astype(np.float32)
    sw = np.asarray(quantize.absmax_scale(jnp.asarray(w), 0, 6.0))
    w_q = np.asarray(quantize.lut_round(jnp.asarray(w * sw)))
    return jnp.asarray(w_q), jnp.asarray(sw)


# --- quantizer family: bit-exact vs oracle ---------------------------------

@pytest.mark.parametrize("shape", _QUANT_SHAPES)
def test_fp4_quant_fuzz(shape):
    rng = np.random.default_rng(SEED)
    for tag, x in _corpus(shape, rng):
        q, s = ops.fp4_quantize(jnp.asarray(x), block_m=16)
        qr, sr = ref.fp4_quant_ref(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr),
                                      err_msg=f"fp4_quant scale [{tag}]")
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr),
                                      err_msg=f"fp4_quant values [{tag}]")
        assert np.all(np.isfinite(np.asarray(q))), tag


@pytest.mark.parametrize("shape", _QUANT_SHAPES)
@pytest.mark.parametrize("lohi", _LOHI_CASES)
def test_fused_row_scale_fuzz(shape, lohi):
    rng = np.random.default_rng(SEED)
    for tag, x in _corpus(shape, rng):
        a = jnp.asarray(x)
        got = ops.fused_row_scale(a, _lohi_arr(lohi), block_m=16, block_k=16)
        want = ref.fused_row_scale_ref(a, _lohi_arr(lohi))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"fused_row_scale [{tag}]")


@pytest.mark.parametrize("shape", _QUANT_SHAPES)
def test_outlier_clamp_fuzz(shape):
    rng = np.random.default_rng(SEED)
    for tag, x in _corpus(shape, rng):
        c, r = ops.outlier_clamp(jnp.asarray(x), -1.5, 2.0, block_m=16)
        cr, rr = ref.outlier_clamp_ref(jnp.asarray(x), -1.5, 2.0)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr),
                                      err_msg=f"outlier_clamp c [{tag}]")
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rr),
                                      err_msg=f"outlier_clamp r [{tag}]")


# --- GEMM family: blocked accumulation vs one-shot oracle ------------------

@pytest.mark.parametrize("mnk", _MNK_SHAPES)
def test_fp4_matmul_fuzz(mnk):
    M, N, K = mnk
    rng = np.random.default_rng(SEED + K)
    w_q, sw = _grid_weights(K, N, rng)
    for tag, x in _corpus((M, K), rng):
        a = jnp.asarray(x)
        a_q, sa = ref.fp4_quant_ref(a)
        got = ops.fp4_matmul_pallas(a_q, w_q, sa, sw, block_m=16,
                                    block_n=16, block_k=16)
        want = ref.fp4_matmul_ref(a_q, w_q, sa, sw)
        assert_close("fp4_matmul", got, want)


@pytest.mark.parametrize("mnk", _MNK_SHAPES)
@pytest.mark.parametrize("lohi", _LOHI_CASES)
def test_fused_fwd_fuzz(mnk, lohi):
    M, N, K = mnk
    rng = np.random.default_rng(SEED + 7 * K)
    w_q, sw = _grid_weights(K, N, rng)
    bounds = _lohi_arr(lohi)
    for tag, x in _corpus((M, K), rng):
        a = jnp.asarray(x)
        sa = ref.fused_row_scale_ref(a, bounds)
        got = ops.fp4_matmul_fused(a, w_q, sa, sw, bounds,
                                   blocks=(16, 16, 16))
        want = ref.fused_quant_matmul_ref(a, w_q, sa, sw, bounds)
        assert_close("fused_fwd", got, want)


@pytest.mark.parametrize("mnk", _MNK_SHAPES)
def test_fused_dgrad_fuzz(mnk):
    M, N, K = mnk
    rng = np.random.default_rng(SEED + 13 * N)
    w_q, sw = _grid_weights(K, N, rng)
    for tag, g_np in _corpus((M, N), rng):
        g = jnp.asarray(g_np)
        got = ops.fp4_dgrad_fused(g, w_q, sw, blocks=(16, 16, 16))
        want = ref.fused_dgrad_ref(g, w_q, sw)
        assert_close("fused_dgrad", got, want)


@pytest.mark.parametrize("mnk", _MNK_SHAPES)
@pytest.mark.parametrize("lohi", _LOHI_CASES)
def test_fused_wgrad_fuzz(mnk, lohi):
    M, N, K = mnk
    rng = np.random.default_rng(SEED + 29 * M)
    bounds = _lohi_arr(lohi)
    # random DGE-shaped mask incl. exact zeros (clipped-interval edges)
    mask_np = rng.uniform(0.0, 3.0, (K, N)).astype(np.float32)
    mask_np[rng.random((K, N)) < 0.1] = 0.0
    mask = jnp.asarray(mask_np)
    g = jnp.asarray(rng.standard_normal((M, N)).astype(np.float32))
    for tag, x in _corpus((M, K), rng):
        a = jnp.asarray(x)
        sa = ref.fused_row_scale_ref(a, bounds)
        got = ops.fp4_wgrad_fused(a, sa, g, mask, bounds,
                                  blocks=(16, 16, 16))
        want = ref.fused_wgrad_ref(a, sa, g, mask, bounds)
        assert_close("fused_wgrad", got, want)


# --- flash attention (S must divide the blocks -- kernel contract) ---------

@pytest.mark.parametrize("shape,blocks", [
    ((1, 64, 2, 8), (16, 16)),
    ((2, 128, 1, 16), (32, 64)),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_fuzz(shape, blocks, causal):
    rng = np.random.default_rng(SEED)
    B, S, H, D = shape
    for scale in (1.0, 30.0):  # logits-saturation stress at 30x
        q, k, v = (jnp.asarray(scale * rng.standard_normal(shape)
                               .astype(np.float32)) for _ in range(3))
        got = ops.flash_attention(q, k, v, causal=causal,
                                  block_q=blocks[0], block_k=blocks[1])
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        assert_close("flash_attention", got, want)


# --- hypothesis-driven sweeps ----------------------------------------------

_ELEMS = st.floats(min_value=-1e4, max_value=1e4, width=32,
                   allow_nan=False, allow_infinity=False)
_SHAPES_2D = hnp.array_shapes(min_dims=2, max_dims=2, min_side=1,
                              max_side=40)


@settings(max_examples=15, deadline=None)
@given(hnp.arrays(np.float32, _SHAPES_2D, elements=_ELEMS))
def test_fp4_quant_property(x_np):
    x = jnp.asarray(x_np)
    q, s = ops.fp4_quantize(x, block_m=8)
    qr, sr = ref.fp4_quant_ref(x)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))


@settings(max_examples=10, deadline=None)
@given(hnp.arrays(np.float32, _SHAPES_2D, elements=_ELEMS))
def test_fused_fwd_property(a_np):
    M, K = a_np.shape
    rng = np.random.default_rng(SEED + M * 1000 + K)  # shape-keyed, seeded
    N = int(rng.integers(1, 24))
    w_q, sw = _grid_weights(K, N, rng)
    bounds = _lohi_arr(None)
    a = jnp.asarray(a_np)
    sa = ref.fused_row_scale_ref(a, bounds)
    got = ops.fp4_matmul_fused(a, w_q, sa, sw, bounds, blocks=(8, 8, 8))
    want = ref.fused_quant_matmul_ref(a, w_q, sa, sw, bounds)
    assert_close("fused_fwd", got, want)


# --- determinism ------------------------------------------------------------

def test_kernels_deterministic():
    """Same input, same bits, twice -- no hidden RNG anywhere."""
    rng = np.random.default_rng(SEED)
    a = jnp.asarray(rng.standard_normal((37, 65)).astype(np.float32))
    for _ in range(2):
        runs = [np.asarray(ops.fp4_quantize(a, block_m=16)[0])
                for _ in range(2)]
        np.testing.assert_array_equal(runs[0], runs[1])
    w_q, sw = _grid_weights(65, 19, rng)
    sa = ref.fused_row_scale_ref(a, _lohi_arr(None))
    outs = [np.asarray(ops.fp4_matmul_fused(a, w_q, sa, sw,
                                            blocks=(16, 16, 16)))
            for _ in range(2)]
    np.testing.assert_array_equal(outs[0], outs[1])
