"""Packing-mask property tests: segment-ID attention isolation.

The contract (docs/data_format.md "Packing semantics"): in a packed
batch, token j may attend to token i only when they belong to the same
fragment (segment_ids equal and nonzero) and i <= j in the fragment's
restarted position order. These tests drive randomized packing layouts
(seeded -- property-style, deterministic in CI) through the dense and
chunked attention paths and assert:

  * isolation: attention over a packed row equals attention over each
    fragment computed alone (no cross-segment leakage, no pad leakage)
  * perturbation: corrupting one segment's k/v never changes another
    segment's outputs -- and *does* without segment masks (the leak the
    masks exist to close)
  * path agreement: dense and chunked produce the same masked result
  * model level: the transformer's packed loss equals the mean of
    per-document losses computed on unpacked batches
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import packing
from repro.models import attention as attn


def _random_layout(rng, batch, seq_len):
    """Random fragment lengths per row, summing to <= seq_len."""
    rows = []
    for _ in range(batch):
        frags, used = [], 0
        while used < seq_len and rng.random() < 0.9:
            L = int(rng.integers(1, seq_len - used + 1))
            frags.append(L)
            used += L
        rows.append(frags)
    return rows


def _packed_qkv(rng, layout, seq_len, h=2, hkv=2, dh=8):
    """Build a packed batch's segment/position grids plus random q,k,v."""
    rows = [[np.zeros(L, np.int32) for L in frags] for frags in layout]
    pb = packing.assemble(rows, seq_len)
    seg = jnp.asarray(pb.arrays["segment_ids"])
    pos = jnp.asarray(pb.arrays["positions"])
    B = len(layout)
    q = jnp.asarray(rng.standard_normal((B, seq_len, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, seq_len, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, seq_len, hkv, dh)), jnp.float32)
    return q, k, v, seg, pos


def _dense(q, k, v, pos, seg):
    return attn.dense_attention(q, k, v, pos, pos, causal=True,
                                q_seg=seg, kv_seg=seg)


@pytest.mark.parametrize("seed", range(8))
def test_packed_equals_per_fragment(seed):
    """Isolation property: packed-row output == each fragment alone."""
    rng = np.random.default_rng(seed)
    S = 24
    layout = _random_layout(rng, batch=2, seq_len=S)
    q, k, v, seg, pos = _packed_qkv(rng, layout, S)
    out = np.asarray(_dense(q, k, v, pos, seg))
    for b, frags in enumerate(layout):
        off = 0
        for L in frags:
            sl = slice(off, off + L)
            solo = attn.dense_attention(
                q[b:b + 1, sl], k[b:b + 1, sl], v[b:b + 1, sl],
                jnp.arange(L), jnp.arange(L), causal=True)
            np.testing.assert_allclose(
                out[b, sl], np.asarray(solo)[0], rtol=2e-5, atol=2e-5,
                err_msg=f"row {b} fragment at {off}:{off+L} leaked")
            off += L


@pytest.mark.parametrize("seed", range(8))
def test_perturbing_other_segment_is_invisible(seed):
    """Corrupt segment 2's k/v: segment 1's outputs must not move (and
    must move when the mask is off -- proves the test has teeth)."""
    rng = np.random.default_rng(100 + seed)
    S = 20
    a = int(rng.integers(4, S - 4))            # two fragments: [0,a) [a,S)
    layout = [[a, S - a]]
    q, k, v, seg, pos = _packed_qkv(rng, layout, S)
    k2 = k.at[:, a:].add(7.0)
    v2 = v.at[:, a:].add(-3.0)

    base = np.asarray(_dense(q, k, v, pos, seg))
    pert = np.asarray(_dense(q, k2, v2, pos, seg))
    np.testing.assert_array_equal(base[:, :a], pert[:, :a])

    # without segments the perturbation IS visible to fragment 1
    no_base = np.asarray(attn.dense_attention(q, k, v, pos, pos))
    no_pert = np.asarray(attn.dense_attention(q, k2, v2, pos, pos))
    assert np.abs(no_base[:, :a] - no_pert[:, :a]).max() > 1e-4


@pytest.mark.parametrize("seed", range(4))
def test_padding_is_invisible(seed):
    """Pad tokens (segment 0, position -1) must not affect real tokens."""
    rng = np.random.default_rng(200 + seed)
    S = 16
    a = int(rng.integers(2, S - 2))
    layout = [[a]]                              # one fragment + padding
    q, k, v, seg, pos = _packed_qkv(rng, layout, S)
    k2 = k.at[:, a:].set(50.0)
    v2 = v.at[:, a:].set(-50.0)
    base = np.asarray(_dense(q, k, v, pos, seg))
    pert = np.asarray(_dense(q, k2, v2, pos, seg))
    np.testing.assert_array_equal(base[:, :a], pert[:, :a])


@pytest.mark.parametrize("seed", range(4))
def test_chunked_matches_dense_with_segments(seed):
    rng = np.random.default_rng(300 + seed)
    S = 32
    layout = _random_layout(rng, batch=2, seq_len=S)
    q, k, v, seg, pos = _packed_qkv(rng, layout, S)
    dense = _dense(q, k, v, pos, seg)
    chunk = attn.chunked_attention(q, k, v, pos, pos, causal=True,
                                   kv_chunk=8, q_seg=seg, kv_seg=seg)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_dispatcher_routes_segments():
    """attention(segments=...) must not take the banded path (which has
    no segment plumbing) and must mask like dense."""
    rng = np.random.default_rng(0)
    S = 24
    q, k, v, seg, pos = _packed_qkv(rng, [[10, 14]], S)
    via_dispatch = attn.attention(q, k, v, pos, pos, causal=True,
                                  window=4, segments=seg)
    direct = attn.dense_attention(q, k, v, pos, pos, causal=True,
                                  window=4, q_seg=seg, kv_seg=seg)
    np.testing.assert_allclose(np.asarray(via_dispatch),
                               np.asarray(direct), rtol=1e-6, atol=1e-6)


def test_model_packed_loss_matches_unpacked():
    """End to end through the transformer: the packed batch's masked
    mean loss equals the token-weighted mean of per-document losses."""
    from repro.configs import get_config
    from repro.core.policy import get_policy
    from repro.models import build_model

    cfg = get_config("llama2-400m").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=128, tie_embeddings=True, loss_chunk=16,
        remat=False, scan_layers=False)
    model = build_model(cfg, get_policy("bf16"))
    params, _ = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(42)
    S = 32
    docs = [rng.integers(1, 128, size=L).astype(np.int32)
            for L in (20, 12, 9)]
    # pack: row0 = [doc0, doc1], row1 = [doc2] + pad
    pb = packing.assemble([[docs[0], docs[1]], [docs[2]]], S)
    batch = {k: jnp.asarray(v) for k, v in pb.arrays.items()}
    packed_lm = float(model.loss(params, batch)[1]["lm_loss"])

    # reference: each doc alone, full-length causal attention
    tot, n = 0.0, 0
    for d in docs:
        one = {"tokens": jnp.asarray(d[None, :])}
        L = len(d) - 1                      # next-token targets
        tot += float(model.loss(params, one)[1]["lm_loss"]) * L
        n += L
    np.testing.assert_allclose(packed_lm, tot / n, rtol=1e-4)
