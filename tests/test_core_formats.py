"""Format tables, LUT rounding, int8 exactness, 4-bit packing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, quantize


def test_e2m1_grid_matches_paper_table4():
    expected = [-6, -4, -3, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 3, 4, 6]
    assert formats.E2M1.values.tolist() == expected
    assert formats.E2M1.max_value == 6.0


def test_e1m2_e3m0_grids_match_paper_table4():
    assert formats.E1M2.values.tolist() == [
        -3.5, -3, -2.5, -2, -1.5, -1, -0.5, 0, 0.5, 1, 1.5, 2, 2.5, 3, 3.5]
    assert formats.E3M0.values.tolist() == [
        -16, -8, -4, -2, -1, -0.5, -0.25, 0, 0.25, 0.5, 1, 2, 4, 8, 16]


def test_lut_round_matches_paper_cuda_thresholds():
    # Paper App. A kernel: explicit threshold chain. Check each branch.
    cases = [(-7.0, -6.0), (-5.1, -6.0), (-4.9, -4.0), (-3.6, -4.0),
             (-3.4, -3.0), (-2.6, -3.0), (-2.4, -2.0), (-1.8, -2.0),
             (-1.7, -1.5), (-1.3, -1.5), (-1.2, -1.0), (-0.8, -1.0),
             (-0.7, -0.5), (-0.3, -0.5), (-0.2, 0.0), (0.2, 0.0),
             (0.3, 0.5), (0.7, 0.5), (0.8, 1.0), (1.2, 1.0), (1.3, 1.5),
             (1.7, 1.5), (1.8, 2.0), (2.4, 2.0), (2.6, 3.0), (3.4, 3.0),
             (3.6, 4.0), (4.9, 4.0), (5.1, 6.0), (7.0, 6.0)]
    x = jnp.asarray([c[0] for c in cases])
    want = np.asarray([c[1] for c in cases])
    got = np.asarray(quantize.lut_round(x))
    np.testing.assert_array_equal(got, want)


def test_grid_values_idempotent_under_rounding():
    v = jnp.asarray(formats.E2M1.values, jnp.float32)
    np.testing.assert_array_equal(np.asarray(quantize.lut_round(v)), np.asarray(v))


def test_int8_codes_exact_roundtrip():
    v = jnp.asarray(formats.E2M1.values, jnp.float32)
    codes = formats.to_int8_codes(v)
    assert codes.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(formats.from_int8_codes(codes)),
                                  np.asarray(v))


def test_int8_gemm_equals_fp4_gemm_exactly():
    rng = np.random.default_rng(0)
    a = quantize.lut_round(jnp.asarray(rng.normal(size=(16, 32)) * 3, jnp.float32))
    w = quantize.lut_round(jnp.asarray(rng.normal(size=(32, 8)) * 3, jnp.float32))
    ref = np.asarray(a, np.float64) @ np.asarray(w, np.float64)
    a8, w8 = formats.to_int8_codes(a), formats.to_int8_codes(w)
    got = np.asarray(jnp.matmul(a8, w8, preferred_element_type=jnp.int32)) / 4.0
    np.testing.assert_array_equal(got, ref)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    x = quantize.lut_round(jnp.asarray(rng.normal(size=(8, 64)) * 4, jnp.float32))
    idx = formats.values_to_indices(x)
    packed = formats.pack_e2m1(idx)
    assert packed.shape == (8, 32) and packed.dtype == jnp.uint8
    back = formats.indices_to_values(formats.unpack_e2m1(packed))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_bf16_represents_grid_exactly():
    v = jnp.asarray(formats.E2M1.values, jnp.float32)
    np.testing.assert_array_equal(np.asarray(v.astype(jnp.bfloat16), np.float32),
                                  np.asarray(v))
