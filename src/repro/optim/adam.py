"""Mixed-precision Adam after FP8-LM (paper §4.1):

  * first moments  m  stored in FP8 (E4M3) + per-tensor f32 scale
  * second moments v  stored in FP16
  * master weights    f32
  * model weights     cast to compute dtype by the forward pass

The fp8 moment storage is *real* (jnp.float8_e4m3fn arrays), not simulated:
update math runs in f32, storage rounds through e4m3 with a fresh absmax
scale each step (matches FP8-LM's per-tensor scaling).

State is a pytree parallel to params; `zero1_specs` extends param specs by
sharding optimizer state over 'data' on the largest divisible replicated
dim (ZeRO-1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quantize


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # moment storage (paper recipe). Set both to "float32" for the BF16
    # baseline arm.
    m_dtype: str = "float8_e4m3fn"
    v_dtype: str = "float16"
    # Per-coordinate update clipping (|mhat/sqrt(vhat)| cap). Required for
    # fp8 first moments: quantization noise in m over coordinates whose v
    # is ~0 (rare embedding rows) otherwise yields unbounded updates --
    # noise/sqrt(0). Adam's update is ~±1 per coordinate in steady state,
    # so a small multiple of 1 is non-binding for healthy coordinates.
    update_clip: float = 3.0


class MomentFP8(NamedTuple):
    """fp8 payload + f32 absmax scale."""
    q: jnp.ndarray
    scale: jnp.ndarray


def _store_m(m_f32, dtype: str):
    if dtype == "float8_e4m3fn":
        q, s = quantize.quantize_fp8(m_f32)
        return MomentFP8(q, s)
    return m_f32.astype(dtype)


def _load_m(m) -> jnp.ndarray:
    if isinstance(m, MomentFP8):
        return quantize.dequantize_fp8(m.q, m.scale)
    return m.astype(jnp.float32)


def init_state(params, cfg: AdamConfig):
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return {
            # copy=True: when p is already f32, a bare astype aliases the
            # param buffer -- donating the state would then donate the same
            # buffer twice (params.X and opt...X.master).
            "master": jnp.array(p, jnp.float32, copy=True),
            "m": _store_m(z, cfg.m_dtype),
            "v": z.astype(cfg.v_dtype),
        }
    return {"t": jnp.zeros((), jnp.int32), "per_param": jax.tree.map(one, params)}


def apply_update(params, grads, state, lr, cfg: AdamConfig):
    """One Adam step. Returns (new_params_in_orig_dtype, new_state)."""
    t = state["t"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** t.astype(jnp.float32)
    c2 = 1.0 - b2 ** t.astype(jnp.float32)

    def one(p, g, s):
        g = g.astype(jnp.float32)
        m = _load_m(s["m"]) * b1 + (1 - b1) * g
        v = s["v"].astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        master = s["master"]
        raw = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.update_clip:
            raw = jnp.clip(raw, -cfg.update_clip, cfg.update_clip)
        upd = raw + cfg.weight_decay * master
        master = master - lr * upd
        return master.astype(p.dtype), {
            "master": master,
            "m": _store_m(m, cfg.m_dtype),
            "v": v.astype(cfg.v_dtype),
        }

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["per_param"])
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, {"t": t, "per_param": new_s}


def global_norm(grads) -> jnp.ndarray:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        grads), norm


# --------------------------------------------------------------------------
# ZeRO-1 sharding for optimizer state
# --------------------------------------------------------------------------

def zero1_specs(param_spec_tree, params, mesh):
    """Extend each param's PartitionSpec by sharding the largest replicated
    divisible dim over 'data' (optimizer-state-only sharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    data = mesh.shape.get("data", 1) if hasattr(mesh.shape, "get") else \
        dict(mesh.shape).get("data", 1)

    def extend(spec: P, p):
        entries = list(spec) + [None] * (p.ndim - len(spec))
        if "data" in [e for ent in entries if ent for e in
                      (ent if isinstance(ent, tuple) else (ent,))]:
            return P(*entries)
        # find the largest dim that is replicated & divisible
        best, best_dim = -1, -1
        for i, (d, e) in enumerate(zip(p.shape, entries)):
            if e is None and d % data == 0 and d > best_dim:
                best, best_dim = i, d
        if best >= 0:
            entries[best] = "data"
        return P(*entries)

    def one(spec, p):
        sp = extend(spec if isinstance(spec, P) else P(*spec), p)
        moment_shard = NamedSharding(mesh, sp)
        return {
            "master": moment_shard,
            "m": MomentFP8(moment_shard,
                           NamedSharding(mesh, P())),
            "v": moment_shard,
        }

    return jax.tree.map(one, param_spec_tree, params,
                        is_leaf=lambda x: isinstance(x, P))
