"""LR schedule (paper §4.1): 5% linear warmup, cosine decay to 10% of peak
over the remaining 95%. Peak 3e-4, weight decay 0.1 (set in AdamConfig)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, total_steps: int, peak_lr: float = 3e-4,
                  warmup_frac: float = 0.05, final_frac: float = 0.10):
    step = jnp.asarray(step, jnp.float32)
    warmup = max(1.0, warmup_frac * total_steps)
    warm_lr = peak_lr * step / warmup
    t = jnp.clip((step - warmup) / max(1.0, total_steps - warmup), 0.0, 1.0)
    cos_lr = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                        (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm_lr, cos_lr)
