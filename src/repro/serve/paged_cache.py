"""Paged KV cache for the continuous-batching serve engine (DESIGN.md §13).

Storage model (vLLM-style, adapted to the repro stack):

  * Device side: every attention layer owns a pool of fixed-size pages
    ``k_pages/v_pages: (n_pages, page_size, n_kv_heads, head_dim)`` in the
    config cache dtype (bf16 / fp8-e4m3 / f32 -- same ``CACHE_DTYPES``
    table as the dense ring cache). Page 0 is a reserved *trash* page:
    writes for padded / inactive positions are routed there so the
    scatter stays shape-stable under jit.
  * Host side: a ``PageAllocator`` free-list hands out page ids (never 0)
    and a per-slot page table ``(n_slots, max_pages_per_slot)`` int32
    (-1 = unallocated) maps token position ``p`` of a slot to device row
    ``table[slot, p // page_size] * page_size + p % page_size``. The page
    table is plain numpy; the engine ships it to the device once per
    step (shape-stable, so no recompilation).

Positions are implicit: pages are allocated in order, so entry ``j`` of
the slot's gathered KV view sits at absolute position ``j``. No kv_pos
array is stored -- validity is ``table entry >= 0 and j < seq_len``.
"""
from __future__ import annotations

import numpy as np

TRASH_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to store ``n_tokens`` cache entries."""
    return max(0, -(-int(n_tokens) // int(page_size)))


class PageAllocator:
    """Host-side free-list over ``n_pages`` device pages.

    Page 0 (``TRASH_PAGE``) is never handed out. ``alloc`` is
    all-or-nothing: a request that does not fit leaves the free list
    untouched and returns None, so the caller can keep the request
    queued (or evict) without partial bookkeeping.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        self._owned: set[int] = set()

    # ------------------------------------------------------------------ api
    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset:
        return frozenset(self._owned)

    def alloc(self, n: int) -> list[int] | None:
        """n pages, or None if they don't all fit (free list unchanged)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            p = int(p)
            if p == TRASH_PAGE:
                raise ValueError("freeing the reserved trash page")
            if p not in self._owned:
                raise ValueError(f"double free / foreign page {p}")
            self._owned.remove(p)
            self._free.append(p)

    def check_invariants(self) -> None:
        """Free list and owned set partition pages 1..n-1 exactly."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate in free list"
        assert not (free & self._owned), "page both free and owned"
        assert free | self._owned == set(range(1, self.n_pages)), \
            "pages leaked or fabricated"
        assert TRASH_PAGE not in free and TRASH_PAGE not in self._owned


class PageTable:
    """Per-slot page table rows + sequence lengths (host numpy).

    The device step consumes ``table``/``seq_lens`` verbatim; the engine
    mutates them only between steps through this class, which keeps the
    allocator and the table consistent (every table entry > 0 is owned
    by the allocator until the slot is released).
    """

    def __init__(self, allocator: PageAllocator, n_slots: int,
                 max_pages_per_slot: int):
        self.allocator = allocator
        self.n_slots = int(n_slots)
        self.max_pages = int(max_pages_per_slot)
        self.table = np.full((n_slots, self.max_pages), -1, np.int32)
        self.seq_lens = np.zeros((n_slots,), np.int32)

    # ---------------------------------------------------------------- slots
    def slot_pages(self, slot: int) -> list[int]:
        row = self.table[slot]
        return [int(p) for p in row if p >= 0]

    def reserve(self, slot: int, n_tokens: int) -> bool:
        """Grow slot ``slot`` so positions [0, seq_lens+n_tokens) have
        pages. All-or-nothing; False when the pool is exhausted."""
        ps = self.allocator.page_size
        have = len(self.slot_pages(slot))
        need = pages_needed(int(self.seq_lens[slot]) + n_tokens, ps) - have
        if need <= 0:
            return True
        if have + need > self.max_pages:
            return False
        pages = self.allocator.alloc(need)
        if pages is None:
            return False
        self.table[slot, have:have + need] = pages
        return True

    def advance(self, slot: int, n_tokens: int = 1) -> None:
        self.seq_lens[slot] += n_tokens

    def release(self, slot: int) -> None:
        """Return every page of the slot to the allocator and clear it."""
        pages = self.slot_pages(slot)
        if pages:
            self.allocator.free(pages)
        self.table[slot] = -1
        self.seq_lens[slot] = 0

    def check_invariants(self) -> None:
        self.allocator.check_invariants()
        seen: set[int] = set()
        for s in range(self.n_slots):
            row = self.table[s]
            pages = [int(p) for p in row if p >= 0]
            # pages are prefix-allocated: no -1 holes before a valid page
            n = len(pages)
            assert all(int(p) >= 0 for p in row[:n]), f"hole in slot {s}"
            assert all(int(p) < 0 for p in row[n:]), f"hole in slot {s}"
            for p in pages:
                assert p != TRASH_PAGE, f"slot {s} maps the trash page"
                assert p in self.allocator.allocated, \
                    f"slot {s} dangles page {p}"
                assert p not in seen, f"page {p} double-mapped"
                seen.add(p)
            assert pages_needed(int(self.seq_lens[s]),
                                self.allocator.page_size) <= n, \
                f"slot {s} has tokens beyond its pages"
        # every owned page is mapped by exactly one slot
        assert seen == set(self.allocator.allocated), \
            "allocator owns pages no slot maps"
