"""Slot-based request scheduling for the continuous-batching serve engine.

The scheduler is pure host-side bookkeeping: a FIFO admission queue, a
fixed array of `n_slots` decode slots (the jitted step's batch dim --
shape-stable by construction), and per-request lifecycle state. Device
work (prefill, decode, page allocation) is driven by `ServeEngine`,
which consults the scheduler for *what* to run each step.

Request lifecycle:  QUEUED -> RUNNING -> (DONE | EVICTED)

Eviction reasons: per-request decode-step timeout, cache-capacity
exhaustion (the engine could not reserve the next page), or explicit
`cancel`. Evicted requests keep whatever tokens they produced.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Iterator

QUEUED, RUNNING, DONE, EVICTED = "queued", "running", "done", "evicted"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    timeout_steps: int | None = None     # decode steps before eviction
    state: str = QUEUED
    slot: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    pos: int = 0                         # next cache write position
    decode_steps: int = 0
    submit_step: int | None = None       # engine step at submit()
    first_token_step: int | None = None  # engine step of first token (TTFT)
    evict_reason: str | None = None

    @property
    def finished(self) -> bool:
        return self.state in (DONE, EVICTED)


class SlotScheduler:
    """Admission queue + fixed decode slots + request registry."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(n_slots)
        self.n_slots = int(n_slots)
        self.slots: list[Request | None] = [None] * self.n_slots
        self.queue: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self._rid = itertools.count()

    # ------------------------------------------------------------ lifecycle
    def submit(self, prompt, max_new_tokens: int, *, now: int,
               timeout_steps: int | None = None) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(max_new_tokens)
        req = Request(rid=next(self._rid), prompt=prompt,
                      max_new_tokens=int(max_new_tokens),
                      timeout_steps=timeout_steps, submit_step=now)
        self.requests[req.rid] = req
        self.queue.append(req)
        return req.rid

    def admissible(self) -> Request | None:
        """Head of the queue if a slot is free (engine then checks pages)."""
        if not self.queue:
            return None
        return self.queue[0] if None in self.slots else None

    def place(self, req: Request) -> int:
        """Move the queue head into a free slot; returns the slot index."""
        assert self.queue and self.queue[0] is req
        slot = self.slots.index(None)
        self.queue.popleft()
        req.state, req.slot, req.pos = RUNNING, slot, len(req.prompt)
        self.slots[slot] = req
        return slot

    def finish(self, req: Request, state: str = DONE,
               reason: str | None = None) -> None:
        assert req.state == RUNNING
        req.state, req.evict_reason = state, reason
        self.slots[req.slot] = None
        req.slot = None

    def cancel(self, rid: int) -> bool:
        """Drop a queued or running request. Running requests are marked
        evicted; the engine frees their pages on its next step."""
        req = self.requests.get(rid)
        if req is None or req.finished:
            return False
        if req.state == QUEUED:
            self.queue.remove(req)
            req.state, req.evict_reason = EVICTED, "cancelled"
        else:
            self.finish(req, EVICTED, "cancelled")
        return True

    # -------------------------------------------------------------- queries
    def running(self) -> Iterator[Request]:
        return (r for r in self.slots if r is not None)

    @property
    def n_running(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or self.n_running > 0

    def timed_out(self) -> list[Request]:
        return [r for r in self.running()
                if r.timeout_steps is not None
                and r.decode_steps >= r.timeout_steps]

    def status(self, rid: int) -> dict:
        req = self.requests[rid]
        return {
            "rid": req.rid, "state": req.state, "tokens": list(req.tokens),
            "evict_reason": req.evict_reason,
            "submit_step": req.submit_step,
            "first_token_step": req.first_token_step,
        }

    def check_invariants(self) -> None:
        for i, r in enumerate(self.slots):
            if r is not None:
                assert r.state == RUNNING and r.slot == i
        assert all(r.state == QUEUED for r in self.queue)
        running = {r.rid for r in self.running()}
        queued = {r.rid for r in self.queue}
        assert not (running & queued)
        for r in self.requests.values():
            if r.state == RUNNING:
                assert r.rid in running
            elif r.state == QUEUED:
                assert r.rid in queued
            else:
                assert r.rid not in running | queued
