"""repro.serve -- serving stack (DESIGN.md §13).

`engine` has the fixed-batch primitives (make_serve_step /
greedy_generate, lowered by launch/dryrun) and the continuous-batching
`ServeEngine`; `scheduler` and `paged_cache` hold the host-side slot and
page bookkeeping.
"""
from .engine import (ServeEngine, greedy_generate, make_serve_step,
                     serve_shardings)
from .paged_cache import PageAllocator, PageTable, pages_needed
from .scheduler import Request, SlotScheduler

__all__ = [
    "ServeEngine", "greedy_generate", "make_serve_step", "serve_shardings",
    "PageAllocator", "PageTable", "pages_needed",
    "Request", "SlotScheduler",
]
