"""Serving: continuous-batching decode engine + prefill/decode steps.

Two layers:

  * `make_serve_step` / `greedy_generate` -- the fixed-batch primitives
    the decode_32k / long_500k dry-run cells lower (one new token for
    every request in the batch against a seq_len-deep cache). Kept as
    the lowering surface for launch/dryrun.
  * `ServeEngine` -- the continuous-batching host engine (DESIGN.md §13):
    slot-based scheduler (serve/scheduler.py), paged KV cache with a
    host-side block allocator (serve/paged_cache.py), per-request
    submit()/poll() API, prefill/decode interleaving, timeout/capacity
    eviction. The jitted step signature is shape-stable: (n_slots,)
    token/position vectors plus an active-slot mask, so admission and
    completion never trigger recompilation.

Cache sharding: batch -> DP axes, cache sequence dim -> 'model' (2D;
DESIGN.md §4); paged pools shard the kv-heads dim over 'model'. fp8
cache storage comes from cfg.cache_dtype as in the dense path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.chaos.hooks import chaos_point
from repro.dist import sharding as shard_rules

from .paged_cache import PageAllocator, PageTable, pages_needed
from .scheduler import DONE, EVICTED, SlotScheduler


def make_serve_step(model, mesh):
    """When `model.policy.obs_metrics` is on, the decode step additionally
    returns a flat quant-health dict (same vocabulary as the train-side
    metrics["obs"]; DESIGN.md §11) harvested inside the jitted step."""
    obs_on = getattr(model.policy, "obs_metrics", False)

    def serve_step(params, cache, tokens, pos):
        with obs.collect(enabled=obs_on) as col:
            logits, cache = model.decode_step(params, cache, tokens, pos)
        # greedy sampling head (sampling params are a host concern)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if col is not None:
            return next_tok, logits, cache, col.harvest()
        return next_tok, logits, cache
    return serve_step


def serve_shardings(model, params, cache, mesh):
    """(param shardings, cache shardings, token sharding) for a serve
    deployment on `mesh`.

    Param shardings come from the model's logical axes (recovered via an
    abstract `model.init` -- no device allocation); cache shardings are
    positional (dist/sharding.py cache rules, incl. paged `*_pages`
    pools); tokens shard their batch dim over the DP axes.
    """
    axes_box = []

    def _init(key):
        p, axes = model.init(key)
        axes_box.append(axes)     # static (strings); keep out of the trace
        return p

    jax.eval_shape(_init, jax.random.PRNGKey(0))
    param_sh = shard_rules.param_shardings(axes_box[0], params, mesh)
    cache_sh = shard_rules.cache_shardings(cache, mesh)
    dps = shard_rules.data_axes(mesh)
    tok_spec = P(dps if len(dps) > 1 else (dps[0] if dps else None))
    return param_sh, cache_sh, NamedSharding(mesh, tok_spec)


def greedy_generate(model, params, batch, steps: int, max_len: int,
                    memory_len: int = 0, obs_writer=None):
    """Host-side loop for examples/tests: prefill then `steps` decode steps.

    `obs_writer` (an `obs.JsonlWriter`-like object with .write(dict)) gets
    one quant-health record per decode step when the model policy has
    `obs_metrics=True`; without a writer the metrics are still computed
    but dropped on the floor (decode health shows up in serve_step users).
    """
    obs_on = getattr(model.policy, "obs_metrics", False)
    B = next(iter(batch.values())).shape[0]
    if memory_len:
        cache = model.init_cache(B, max_len, memory_len=memory_len)
    else:
        cache = model.init_cache(B, max_len)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    if "tokens" in batch:
        pos0 = batch["tokens"].shape[1]
    else:
        pos0 = batch["embeds"].shape[1]

    if obs_on:
        def _step(params, cache, tok, pos):
            with obs.collect() as col:
                logits, cache = model.decode_step(params, cache, tok, pos)
            return logits, cache, col.harvest()
        step = jax.jit(_step)
    else:
        step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for t in range(steps - 1):
        if obs_on:
            logits, cache, health = step(params, cache, tok,
                                         jnp.int32(pos0 + t))
            if obs_writer is not None:
                host = {k: float(v) for k, v in
                        jax.device_get(health).items()}
                obs_writer.write({"decode_step": t, **host})
        else:
            logits, cache = step(params, cache, tok, jnp.int32(pos0 + t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ===========================================================================
# Continuous-batching engine
# ===========================================================================

class ServeEngine:
    """Continuous-batching greedy-decode engine over an FP4 model stack.

    Host API:
        eng = ServeEngine(model, params, n_slots=8, max_len=128)
        rid = eng.submit([tok, tok, ...], max_new_tokens=16)
        eng.step()            # one engine iteration (admit + decode)
        eng.poll(rid)         # {"state", "tokens", ...}
        eng.run()             # step() until all requests drain

    `paged=True` (default) stores KV in per-layer page pools with a
    host-side block allocator; `paged=False` keeps the dense per-slot
    ring cache (same numerics -- the equivalence battery asserts
    token-identical outputs between the two). Both modes require an
    attention-only layer plan (model.supports_paged).
    """

    def __init__(self, model, params, *, n_slots: int = 8,
                 max_len: int = 256, prefill_len: int | None = None,
                 paged: bool = True, page_size: int = 16,
                 n_pages: int | None = None, mesh=None, obs_writer=None,
                 default_timeout_steps: int | None = None):
        model._check_paged()          # both modes need per-slot positions
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.prefill_len = int(prefill_len or min(64, max_len))
        self.paged = bool(paged)
        self.obs_writer = obs_writer
        self.obs_on = getattr(model.policy, "obs_metrics", False)
        self.default_timeout_steps = default_timeout_steps
        self.sched = SlotScheduler(n_slots)
        self.step_count = 0
        self._ttft_s: dict[int, float] = {}       # rid -> wall-clock TTFT
        self._submit_s: dict[int, float] = {}
        self.tokens_emitted = 0

        if self.paged:
            self._pages_per_slot = pages_needed(self.max_len, page_size)
            if n_pages is None:
                n_pages = self.n_slots * self._pages_per_slot + 1
            self.allocator = PageAllocator(n_pages, page_size)
            self.table = PageTable(self.allocator, self.n_slots,
                                   self._pages_per_slot)
            self.cache = model.init_paged_cache(n_pages, page_size)
        else:
            self.allocator = None
            self.table = None
            self.cache = model.init_cache(self.n_slots, self.max_len)

        if mesh is not None:
            p_sh, c_sh, _ = serve_shardings(model, params, self.cache, mesh)
            self.params = jax.device_put(params, p_sh)
            self.cache = jax.device_put(self.cache, c_sh)

        self._build_steps()

    # ------------------------------------------------------------- jitted fns
    def _build_steps(self):
        model, obs_on = self.model, self.obs_on

        if self.paged:
            def prefill(params, batch, pages, table_row):
                return model.prefill_paged(params, batch, pages, table_row)

            def decode(params, pages, tokens, pos, table, active):
                with obs.collect(enabled=obs_on) as col:
                    logits, pages = model.decode_step_paged(
                        params, pages, tokens, pos, table, active)
                health = col.harvest() if col is not None else {}
                return logits, pages, health
        else:
            def prefill(params, batch, cache):
                return model.prefill(params, batch, cache)

            def decode(params, cache, tokens, pos, active):
                with obs.collect(enabled=obs_on) as col:
                    logits, cache = model.decode_step(params, cache,
                                                      tokens, pos)
                health = col.harvest() if col is not None else {}
                return logits, cache, health

            def insert(big, small, slot):
                return jax.tree.map(lambda b, s: b.at[slot].set(s[0]),
                                    big, small)
            self._insert = jax.jit(insert)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    # ----------------------------------------------------------------- submit
    def submit(self, prompt, max_new_tokens: int,
               timeout_steps: int | None = None) -> int:
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if len(prompt) > self.prefill_len:
            raise ValueError(f"prompt len {len(prompt)} > prefill_len "
                             f"{self.prefill_len}")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError("prompt + max_new_tokens exceeds max_len "
                             f"{self.max_len}")
        rid = self.sched.submit(
            prompt, max_new_tokens, now=self.step_count,
            timeout_steps=(self.default_timeout_steps if timeout_steps is None
                           else timeout_steps))
        self._submit_s[rid] = time.monotonic()
        return rid

    def poll(self, rid: int) -> dict:
        st = self.sched.status(rid)
        st["ttft_s"] = self._ttft_s.get(rid)
        return st

    @property
    def busy(self) -> bool:
        return self.sched.busy

    def cancel(self, rid: int) -> bool:
        req = self.sched.requests.get(rid)
        slot = req.slot if req is not None else None
        ok = self.sched.cancel(rid)
        if ok and self.paged and slot is not None:
            self.table.release(slot)
        return ok

    # ------------------------------------------------------------- admission
    def _padded_prompt(self, prompt: list[int]):
        """Left-pad to prefill_len: pads get position < 0 (masked as KV,
        trash-paged on write); the last row position is always the final
        prompt token, so last-position logits are valid for every slot."""
        S, L = self.prefill_len, len(prompt)
        toks = np.zeros((1, S), np.int32)
        toks[0, S - L:] = prompt
        positions = (np.arange(S, dtype=np.int32) - (S - L))[None]
        return {"tokens": jnp.asarray(toks),
                "positions": jnp.asarray(positions)}

    def _admit(self) -> None:
        while True:
            req = self.sched.admissible()
            if req is None:
                return
            n_prompt_pages = (pages_needed(len(req.prompt),
                                           self.allocator.page_size)
                              if self.paged else 0)
            if self.paged and self.allocator.available < n_prompt_pages:
                return                        # head-of-line blocks on pages
            slot = self.sched.place(req)
            batch = self._padded_prompt(req.prompt)
            if self.paged:
                ok = self.table.reserve(slot, len(req.prompt))
                assert ok, "reserve failed after availability check"
                table_row = jnp.asarray(self.table.table[slot:slot + 1])
                logits, self.cache = self._prefill(self.params, batch,
                                                   self.cache, table_row)
                self.table.advance(slot, len(req.prompt))
            else:
                small = self.model.init_cache(1, self.max_len)
                logits, small = self._prefill(self.params, batch, small)
                self.cache = self._insert(self.cache, small,
                                          jnp.int32(slot))
            tok = int(jax.device_get(jnp.argmax(logits, axis=-1))[0])
            req.tokens.append(tok)
            req.first_token_step = self.step_count
            self._ttft_s[req.rid] = time.monotonic() - self._submit_s[req.rid]
            self.tokens_emitted += 1
            self._maybe_finish(req)

    # ----------------------------------------------------------------- decode
    def _evict(self, req, reason: str) -> None:
        slot = req.slot
        self.sched.finish(req, EVICTED, reason)
        if self.paged:
            self.table.release(slot)

    def _maybe_finish(self, req) -> None:
        if len(req.tokens) >= req.max_new_tokens:
            slot = req.slot
            self.sched.finish(req, DONE)
            if self.paged:
                self.table.release(slot)

    def _decode_batch(self) -> dict:
        running = list(self.sched.running())
        if not running:
            return {}
        if self.paged:
            for req in list(running):
                if not self.table.reserve(req.slot, 1):
                    self._evict(req, "cache capacity")
            running = list(self.sched.running())
            if not running:
                return {}
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for req in running:
            tokens[req.slot, 0] = req.tokens[-1]
            pos[req.slot] = req.pos
            active[req.slot] = True
        if self.paged:
            logits, self.cache, health = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(self.table.table),
                jnp.asarray(active))
        else:
            logits, self.cache, health = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(active))
        nxt = np.asarray(jax.device_get(jnp.argmax(logits, axis=-1)))
        for req in running:
            req.tokens.append(int(nxt[req.slot]))
            req.pos += 1
            req.decode_steps += 1
            self.tokens_emitted += 1
            if self.paged:
                self.table.advance(req.slot, 1)
            self._maybe_finish(req)
        return {"health": health, "running": running}

    # ------------------------------------------------------------------- step
    def step(self) -> None:
        """One engine iteration: timeout eviction, admission (+prefill of
        newly placed requests), then one batched decode step."""
        # chaos seam: scenario handlers get the live engine to cancel
        # requests / kill slots mid-flight (DESIGN.md §15)
        chaos_point("serve.pre_step", engine=self, step=self.step_count)
        for req in self.sched.timed_out():
            self._evict(req, "timeout")
        self._admit()
        out = self._decode_batch()
        if self.obs_writer is not None and out:
            health = {}
            if self.obs_on and out["health"]:
                health = {k: float(v) for k, v in
                          jax.device_get(out["health"]).items()}
            for req in out["running"]:
                self.obs_writer.write({
                    "kind": "serve_decode_health",
                    "engine_step": self.step_count, "slot": req.slot,
                    "rid": req.rid, "pos": int(req.pos),
                    "tokens_done": len(req.tokens), **health})
        self.step_count += 1

    def run(self, max_steps: int = 10_000) -> dict:
        """step() until every submitted request drains (or max_steps)."""
        steps = 0
        while self.sched.busy and steps < max_steps:
            self.step()
            steps += 1
        if self.sched.busy:
            raise RuntimeError(f"requests still in flight after "
                               f"{max_steps} steps")
        return {rid: self.poll(rid) for rid in self.sched.requests}

    # -------------------------------------------------------------- plumbing
    def check_invariants(self) -> None:
        self.sched.check_invariants()
        if self.paged:
            self.table.check_invariants()
