"""Serving: prefill + batched decode steps with sharded KV caches.

`make_serve_step` returns the jitted single-token decode function the
decode_32k / long_500k dry-run cells lower: one new token for every request
in the batch against a seq_len-deep cache. Cache sharding: batch -> DP axes,
cache sequence dim -> 'model' (2D; DESIGN.md §4), fp8 cache storage
optional per config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.dist import sharding as shard_rules


def make_serve_step(model, mesh):
    """When `model.policy.obs_metrics` is on, the decode step additionally
    returns a flat quant-health dict (same vocabulary as the train-side
    metrics["obs"]; DESIGN.md §11) harvested inside the jitted step."""
    obs_on = getattr(model.policy, "obs_metrics", False)

    def serve_step(params, cache, tokens, pos):
        with obs.collect(enabled=obs_on) as col:
            logits, cache = model.decode_step(params, cache, tokens, pos)
        # greedy sampling head (sampling params are a host concern)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if col is not None:
            return next_tok, logits, cache, col.harvest()
        return next_tok, logits, cache
    return serve_step


def serve_shardings(model, params, cache, mesh):
    """(param shardings, cache shardings, token sharding)."""
    _, axes = jax.eval_shape(lambda k: model.init(k),
                             jax.random.PRNGKey(0))  # axes only
    return None  # placeholder; launch/dryrun builds these directly


def greedy_generate(model, params, batch, steps: int, max_len: int,
                    memory_len: int = 0, obs_writer=None):
    """Host-side loop for examples/tests: prefill then `steps` decode steps.

    `obs_writer` (an `obs.JsonlWriter`-like object with .write(dict)) gets
    one quant-health record per decode step when the model policy has
    `obs_metrics=True`; without a writer the metrics are still computed
    but dropped on the floor (decode health shows up in serve_step users).
    """
    obs_on = getattr(model.policy, "obs_metrics", False)
    B = next(iter(batch.values())).shape[0]
    if memory_len:
        cache = model.init_cache(B, max_len, memory_len=memory_len)
    else:
        cache = model.init_cache(B, max_len)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    if "tokens" in batch:
        pos0 = batch["tokens"].shape[1]
    else:
        pos0 = batch["embeds"].shape[1]

    if obs_on:
        def _step(params, cache, tok, pos):
            with obs.collect() as col:
                logits, cache = model.decode_step(params, cache, tok, pos)
            return logits, cache, col.harvest()
        step = jax.jit(_step)
    else:
        step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for t in range(steps - 1):
        if obs_on:
            logits, cache, health = step(params, cache, tok,
                                         jnp.int32(pos0 + t))
            if obs_writer is not None:
                host = {k: float(v) for k, v in
                        jax.device_get(health).items()}
                obs_writer.write({"decode_step": t, **host})
        else:
            logits, cache = step(params, cache, tok, jnp.int32(pos0 + t))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
