"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

The decay w_t = exp(-exp(w0 + tanh(x @ A) @ B)) is data-dependent (the
paper's headline Finch feature); token-shift mixing uses static learned
interpolation (the LoRA'd dynamic mix of the full release is omitted --
documented deviation, DESIGN.md §9).

The WKV recurrence is a linear scan over time:
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
Per-step state is (B, H, hd, hd). On real TPU this is the natural target
for a chunked Pallas kernel (kernels/ has the GeMM kernels; the WKV chunk
kernel is listed as a §Perf item). All projections (r/k/v/g/o and
channel-mix) are GeMMs -> fp4_linear.

Scan inventory: trip_count = S, body FLOPs ~= 4*B*D*hd (outer products +
readout) -- reported analytically for the roofline correction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from .layers import rms_norm
from .param import ParamFactory

LORA_R = 64


def _dims(cfg):
    H = cfg.d_model // cfg.ssm_head_dim if cfg.ssm_head_dim else cfg.n_heads
    return H, cfg.d_model // H


def init_rwkv(pf: ParamFactory, cfg):
    D = cfg.d_model
    H, hd = _dims(cfg)
    return {
        "ln_t": pf.ones((D,), (None,)),
        "ln_c": pf.ones((D,), (None,)),
        # token-shift interpolation weights for r,k,v,g,w
        "mu": pf.const(jnp.full((5, D), 0.5), (None, None)),
        "w0": pf.const(jnp.full((D,), -1.0), (None,)),
        "w_lora_a": pf.dense(D, LORA_R, ("embed", None), scale=0.01),
        "w_lora_b": pf.dense(LORA_R, D, (None, "embed"), scale=0.01),
        "wr": pf.dense(D, D, ("embed", "heads")),
        "wk": pf.dense(D, D, ("embed", "heads")),
        "wv": pf.dense(D, D, ("embed", "heads")),
        "wg": pf.dense(D, D, ("embed", "heads")),
        "wo": pf.dense(D, D, ("heads", "embed")),
        "u": pf.zeros((H, hd), ("heads", None)),
        "ln_x": pf.ones((D,), (None,)),
        # channel mix
        "mu_ck": pf.const(jnp.full((D,), 0.5), (None,)),
        "mu_cr": pf.const(jnp.full((D,), 0.5), (None,)),
        "wck": pf.dense(D, cfg.d_ff, ("embed", "mlp")),
        "wcv": pf.dense(cfg.d_ff, D, ("mlp", "embed")),
        "wcr": pf.dense(D, D, ("embed", "embed2")),
    }


def _shift(x):
    """prev-token shift: y_t = x_{t-1}, y_0 = 0."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _time_mix_inputs(p, h, h_prev, cfg, policy):
    """h: (B,S,D) normed input; h_prev: shifted. Returns r,k,v,g,w heads."""
    B, S, D = h.shape
    H, hd = _dims(cfg)
    mu = p["mu"].astype(h.dtype)
    xr, xk, xv, xg, xw = [h + (h_prev - h) * mu[i] for i in range(5)]
    r = fp4_linear(xr, p["wr"], policy=policy).reshape(B, S, H, hd)
    k = fp4_linear(xk, p["wk"], policy=policy).reshape(B, S, H, hd)
    v = fp4_linear(xv, p["wv"], policy=policy).reshape(B, S, H, hd)
    g = jax.nn.silu(fp4_linear(xg, p["wg"], policy=policy))
    # data-dependent decay (Finch): w in (0,1)
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(h.dtype)) @ \
        p["w_lora_b"].astype(h.dtype)
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)))
    return r, k, v, g, w.reshape(B, S, H, hd)


def _wkv_scan(r, k, v, w, u, state0):
    """Linear-time WKV. r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd).
    Returns (out (B,S,H,hd), final state). f32 state for stability."""
    def body(state, inp):
        rt, kt, vt, wt = inp                     # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, out

    rs, ks, vs, ws = [t.transpose(1, 0, 2, 3).astype(jnp.float32)
                      for t in (r, k, v, w)]
    state, outs = jax.lax.scan(body, state0, (rs, ks, vs, ws))
    return outs.transpose(1, 0, 2, 3), state


def rwkv_train(p, x, positions, cfg, layer, policy: QuantPolicy):
    B, S, D = x.shape
    H, hd = _dims(cfg)
    # --- time mix ---
    h = rms_norm(x, p["ln_t"])
    r, k, v, g, w = _time_mix_inputs(p, h, _shift(h), cfg, policy)
    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    out, _ = _wkv_scan(r, k, v, w, p["u"].astype(jnp.float32), state0)
    out = rms_norm(out.reshape(B, S, D).astype(x.dtype), p["ln_x"]) * g
    x = x + fp4_linear(out, p["wo"], policy=policy)
    # --- channel mix ---
    h = rms_norm(x, p["ln_c"])
    hp = _shift(h)
    xk = h + (hp - h) * p["mu_ck"].astype(h.dtype)
    xr = h + (hp - h) * p["mu_cr"].astype(h.dtype)
    kk = jnp.square(jax.nn.relu(fp4_linear(xk, p["wck"], policy=policy)))
    rr = jax.nn.sigmoid(fp4_linear(xr, p["wcr"], policy=policy))
    return x + rr * fp4_linear(kk, p["wcv"], policy=policy)


def rwkv_prefill(p, x, positions, cache, cfg, layer, policy: QuantPolicy):
    """Parallel prompt processing; returns final WKV state + shift tails."""
    B, S, D = x.shape
    H, hd = _dims(cfg)
    h = rms_norm(x, p["ln_t"])
    r, k, v, g, w = _time_mix_inputs(p, h, _shift(h), cfg, policy)
    out, state = _wkv_scan(r, k, v, w, p["u"].astype(jnp.float32),
                           cache["state"])
    out = rms_norm(out.reshape(B, S, D).astype(x.dtype), p["ln_x"]) * g
    x = x + fp4_linear(out, p["wo"], policy=policy)
    h2 = rms_norm(x, p["ln_c"])
    hp = _shift(h2)
    xk = h2 + (hp - h2) * p["mu_ck"].astype(h2.dtype)
    xr = h2 + (hp - h2) * p["mu_cr"].astype(h2.dtype)
    kk = jnp.square(jax.nn.relu(fp4_linear(xk, p["wck"], policy=policy)))
    rr = jax.nn.sigmoid(fp4_linear(xr, p["wcr"], policy=policy))
    x = x + rr * fp4_linear(kk, p["wcv"], policy=policy)
    new_cache = {"state": state,
                 "x_prev_t": h[:, -1:].astype(jnp.float32),
                 "x_prev_c": h2[:, -1:].astype(jnp.float32)}
    return x, new_cache


def init_rwkv_cache(cfg, layer, batch: int, max_len: int):
    D = cfg.d_model
    H, hd = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_prev_t": jnp.zeros((batch, 1, D), jnp.float32),
        "x_prev_c": jnp.zeros((batch, 1, D), jnp.float32),
    }


def rwkv_decode(p, x, cache, pos, cfg, layer, policy: QuantPolicy):
    B = x.shape[0]
    D = cfg.d_model
    H, hd = _dims(cfg)
    h = rms_norm(x, p["ln_t"])
    h_prev = cache["x_prev_t"].astype(h.dtype)
    r, k, v, g, w = _time_mix_inputs(p, h, h_prev, cfg, policy)
    rt, kt, vt, wt = [t[:, 0].astype(jnp.float32) for t in (r, k, v, w)]
    kv = kt[..., :, None] * vt[..., None, :]
    u = p["u"].astype(jnp.float32)
    out = jnp.einsum("bhk,bhkv->bhv", rt, cache["state"] + u[None, :, :, None] * kv)
    state = wt[..., :, None] * cache["state"] + kv
    out = rms_norm(out.reshape(B, 1, D).astype(x.dtype), p["ln_x"]) * g
    x = x + fp4_linear(out, p["wo"], policy=policy)

    h2 = rms_norm(x, p["ln_c"])
    h2_prev = cache["x_prev_c"].astype(h2.dtype)
    xk = h2 + (h2_prev - h2) * p["mu_ck"].astype(h2.dtype)
    xr = h2 + (h2_prev - h2) * p["mu_cr"].astype(h2.dtype)
    kk = jnp.square(jax.nn.relu(fp4_linear(xk, p["wck"], policy=policy)))
    rr = jax.nn.sigmoid(fp4_linear(xr, p["wcr"], policy=policy))
    x = x + rr * fp4_linear(kk, p["wcv"], policy=policy)
    new_cache = {"state": state, "x_prev_t": h.astype(jnp.float32),
                 "x_prev_c": h2.astype(jnp.float32)}
    return x, new_cache
