"""Transformer blocks: attention sublayer (GQA variants), dense FFN, MoE FFN.

Every GeMM goes through `fp4_linear` (the paper's contribution); norms,
rope, softmax, router and residual math stay high-precision per §4.1.

Block interface (used by transformer.py):
    init_layer(pf, cfg, layer)                     -> Boxed tree
    layer_train(p, x, positions, cfg, layer, pol)  -> (x, aux_loss)
    layer_decode(p, x, cache, pos, cfg, layer, pol)-> (x, cache)
    init_layer_cache(cfg, layer, batch, max_len)   -> cache dict
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from . import attention as attn_mod
from .layers import ACTIVATIONS, apply_rope, rms_norm
from .param import Boxed, ParamFactory

CACHE_DTYPES = {"bfloat16": jnp.bfloat16, "float8_e4m3fn": jnp.float8_e4m3fn,
                "float32": jnp.float32}


def _norm(p, x, cfg):
    return rms_norm(x, p, plus_one=cfg.norm_plus_one)


# ===========================================================================
# Attention sublayer (GQA + biases + qk-norm + softcap + local/global)
# ===========================================================================

def init_attn(pf: ParamFactory, cfg, layer: dict):
    dh = cfg.resolved_head_dim
    p = {
        "wq": pf.dense(cfg.d_model, cfg.n_heads * dh, ("embed", "heads")),
        "wk": pf.dense(cfg.d_model, cfg.n_kv_heads * dh, ("embed", "kv_heads")),
        "wv": pf.dense(cfg.d_model, cfg.n_kv_heads * dh, ("embed", "kv_heads")),
        "wo": pf.dense(cfg.n_heads * dh, cfg.d_model, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = pf.zeros((cfg.n_heads * dh,), ("heads",))
        p["bk"] = pf.zeros((cfg.n_kv_heads * dh,), ("kv_heads",))
        p["bv"] = pf.zeros((cfg.n_kv_heads * dh,), ("kv_heads",))
    if cfg.qk_norm:
        p["q_norm"] = pf.ones((dh,), (None,))
        p["k_norm"] = pf.ones((dh,), (None,))
    return p


def _qkv(p, x, cfg, layer, policy, positions):
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = fp4_linear(x, p["wq"], p.get("bq"), policy=policy, name="wq")
    k = fp4_linear(x, p["wk"], p.get("bk"), policy=policy, name="wk")
    v = fp4_linear(x, p["wv"], p.get("bv"), policy=policy, name="wv")
    q = q.reshape(B, S, cfg.n_heads, dh)
    k = k.reshape(B, S, cfg.n_kv_heads, dh)
    v = v.reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    theta = layer.get("rope_theta", cfg.rope_theta)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attn_train(p, x, positions, cfg, layer, policy: QuantPolicy,
               segments=None):
    q, k, v = _qkv(p, x, cfg, layer, policy, positions)
    out = attn_mod.attention(
        q, k, v, positions, positions, causal=layer.get("causal", True),
        window=layer.get("window"), softcap=cfg.attn_softcap,
        kv_chunk=cfg.attn_chunk, segments=segments)
    out = out.reshape(*x.shape[:2], -1)
    return fp4_linear(out, p["wo"], policy=policy, name="wo")


def init_attn_cache(cfg, layer, batch: int, max_len: int):
    dh = cfg.resolved_head_dim
    window = layer.get("window")
    cap = min(window, max_len) if window else max_len
    dt = CACHE_DTYPES[cfg.cache_dtype]
    return {
        "k": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), dt),
        "v": jnp.zeros((batch, cap, cfg.n_kv_heads, dh), dt),
        "kv_pos": jnp.full((batch, cap), -1, jnp.int32),
    }


def _ring_write(cache, k, v, positions):
    """Write (k, v, positions) for a full prefix into a ring-buffer cache.
    k/v: (B, S, Hkv, Dh); positions: (S,) or (B, S). Keeps the last `cap`
    positions.

    Ring slot == position % cap (not sequence index % cap): with ragged
    left-padded prefill (per-batch positions, pads < 0) the later decode
    steps index the ring by absolute position, so prefill must bucket by
    position too. Pad entries land at slots (cap - pad)..(cap - 1) with
    kv_pos = -1; real entries may later overwrite them, never each other
    (positions within a row are consecutive, so any window of <= cap of
    them is distinct mod cap)."""
    cap = cache["k"].shape[1]
    if positions.ndim == 1:
        # batch-uniform contiguous prefix: slot == position % cap
        S = positions.shape[0]
        take = min(S, cap)
        slots = positions[S - take:].astype(jnp.int32) % cap
        ck = cache["k"].at[:, slots].set(
            k[:, S - take:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(
            v[:, S - take:].astype(cache["v"].dtype))
        cpos = cache["kv_pos"].at[:, slots].set(
            jnp.broadcast_to(positions[S - take:][None],
                             (k.shape[0], take)))
        return {"k": ck, "v": cv, "kv_pos": cpos}
    B, S = positions.shape
    take = min(S, cap)
    pos_t = positions[:, S - take:].astype(jnp.int32)
    slots = pos_t % cap                                       # (B, take)
    b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
    ck = cache["k"].at[b_idx, slots].set(
        k[:, S - take:].astype(cache["k"].dtype))
    cv = cache["v"].at[b_idx, slots].set(
        v[:, S - take:].astype(cache["v"].dtype))
    cpos = cache["kv_pos"].at[b_idx, slots].set(pos_t)
    return {"k": ck, "v": cv, "kv_pos": cpos}


def attn_prefill(p, x, positions, cache, cfg, layer, policy: QuantPolicy):
    """Parallel prompt processing + cache fill."""
    q, k, v = _qkv(p, x, cfg, layer, policy, positions)
    out = attn_mod.attention(
        q, k, v, positions, positions, causal=layer.get("causal", True),
        window=layer.get("window"), softcap=cfg.attn_softcap,
        kv_chunk=cfg.attn_chunk)
    out = out.reshape(*x.shape[:2], -1)
    y = fp4_linear(out, p["wo"], policy=policy, name="wo")
    return y, _ring_write(cache, k, v, positions)


def attn_decode(p, x, cache, pos, cfg, layer, policy: QuantPolicy):
    """x: (B,1,D); pos: scalar int32 current position, or (B,) int32 for
    per-slot positions (continuous batching -- every slot of the batch is
    at its own depth). Ring-buffer write at slot position % cap."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    cap = cache["k"].shape[1]
    if pos.ndim == 0:
        positions = jnp.full((B, 1), pos, jnp.int32)
        q, k, v = _qkv(p, x, cfg, layer, policy, positions)
        idx = pos % cap
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache["kv_pos"], positions,
                                            (0, idx))
    else:
        positions = pos[:, None]                              # (B,1)
        q, k, v = _qkv(p, x, cfg, layer, policy, positions)
        b_idx = jnp.arange(B, dtype=jnp.int32)
        idx = pos % cap
        ck = cache["k"].at[b_idx, idx].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, idx].set(v[:, 0].astype(cache["v"].dtype))
        cpos = cache["kv_pos"].at[b_idx, idx].set(pos)
    out = attn_mod.dense_attention(
        q, ck.astype(q.dtype), cv.astype(q.dtype), positions, cpos,
        causal=True, window=layer.get("window"), softcap=cfg.attn_softcap)
    out = out.reshape(B, 1, -1)
    y = fp4_linear(out, p["wo"], policy=policy, name="wo")
    return y, {"k": ck, "v": cv, "kv_pos": cpos}


# ===========================================================================
# Paged KV cache paths (serve engine; DESIGN.md §13). Storage lives in
# per-layer page pools (n_pages, page_size, Hkv, Dh); the page table and
# per-slot lengths are owned by serve/paged_cache.py on the host. Page 0
# is the trash page: padded / inactive writes are routed there.
# ===========================================================================

def init_attn_pages(cfg, n_pages: int, page_size: int):
    dh = cfg.resolved_head_dim
    dt = CACHE_DTYPES[cfg.cache_dtype]
    return {
        "k_pages": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, dh), dt),
        "v_pages": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, dh), dt),
    }


def _paged_write(pages, k, v, page_table, positions, active=None):
    """Scatter (k, v) into the layer's page pool by absolute position.

    k/v: (B,S,Hkv,Dh); page_table: (B,P) int32; positions: (B,S) int32
    (pads < 0). Writes for invalid positions -- pad, unmapped page, or
    inactive slot -- go to flat row 0 (the trash page), keeping the
    scatter shape-stable under jit. Distinct slots own distinct pages,
    so real destinations never collide across the batch."""
    ps = pages["k_pages"].shape[1]
    B, S = positions.shape
    pclip = jnp.maximum(positions, 0)
    page = jnp.take_along_axis(page_table, pclip // ps, axis=1)   # (B,S)
    valid = (positions >= 0) & (page > 0)
    if active is not None:
        valid &= active[:, None]
    dest = jnp.where(valid, page * ps + pclip % ps, 0).reshape(-1)
    tail = pages["k_pages"].shape[2:]
    kf = pages["k_pages"].reshape(-1, *tail)
    vf = pages["v_pages"].reshape(-1, *tail)
    kf = kf.at[dest].set(k.reshape(B * S, *tail).astype(kf.dtype))
    vf = vf.at[dest].set(v.reshape(B * S, *tail).astype(vf.dtype))
    shape = pages["k_pages"].shape
    return {"k_pages": kf.reshape(shape), "v_pages": vf.reshape(shape)}


def attn_prefill_paged(p, x, positions, pages, page_table, cfg, layer,
                       policy: QuantPolicy):
    """Prompt processing into a paged cache. positions: (B,S), pads < 0
    (left-padded ragged batches); attention over the prompt itself runs
    on the in-flight k/v (no page read-back)."""
    q, k, v = _qkv(p, x, cfg, layer, policy, positions)
    out = attn_mod.attention(
        q, k, v, positions, positions, causal=layer.get("causal", True),
        window=layer.get("window"), softcap=cfg.attn_softcap,
        kv_chunk=cfg.attn_chunk)
    out = out.reshape(*x.shape[:2], -1)
    y = fp4_linear(out, p["wo"], policy=policy, name="wo")
    return y, _paged_write(pages, k, v, page_table, positions)


def attn_decode_paged(p, x, pages, pos, page_table, active, cfg, layer,
                      policy: QuantPolicy):
    """One token per slot against the paged cache. x: (B,1,D); pos: (B,)
    per-slot write position; active: (B,) bool slot mask."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]                                  # (B,1)
    q, k, v = _qkv(p, x, cfg, layer, policy, positions)
    pages = _paged_write(pages, k, v, page_table, positions, active)
    seq_lens = jnp.where(active, pos + 1, 0)
    out = attn_mod.paged_attention(
        q, pages["k_pages"], pages["v_pages"], page_table, positions,
        seq_lens, window=layer.get("window"), softcap=cfg.attn_softcap)
    out = out.reshape(B, 1, -1)
    y = fp4_linear(out, p["wo"], policy=policy, name="wo")
    return y, pages


# ===========================================================================
# Dense FFN (SwiGLU / GeGLU / plain MLP)
# ===========================================================================

def init_ffn(pf: ParamFactory, cfg, d_ff: int | None = None, glu: bool = True):
    d_ff = d_ff or cfg.d_ff
    p = {"wd": pf.dense(d_ff, cfg.d_model, ("mlp", "embed"))}
    if glu:
        p["wg"] = pf.dense(cfg.d_model, d_ff, ("embed", "mlp"))
        p["wu"] = pf.dense(cfg.d_model, d_ff, ("embed", "mlp"))
    else:
        p["wu"] = pf.dense(cfg.d_model, d_ff, ("embed", "mlp"))
    return p


def ffn_apply(p, x, cfg, policy: QuantPolicy):
    act = ACTIVATIONS[cfg.act]
    if "wg" in p:
        h = act(fp4_linear(x, p["wg"], policy=policy, name="wg")) * \
            fp4_linear(x, p["wu"], policy=policy, name="wu")
    else:
        h = act(fp4_linear(x, p["wu"], policy=policy, name="wu"))
    return fp4_linear(h, p["wd"], policy=policy, name="wd")


# ===========================================================================
# MoE FFN: top-k router (bf16) + capacity-factor gather dispatch + FP4
# expert GeMMs, experts sharded over 'expert' (-> mesh 'model').
# ===========================================================================

def init_moe(pf: ParamFactory, cfg):
    E, F = cfg.n_experts, cfg.moe_d_ff
    return {
        "router": pf.dense(cfg.d_model, E, ("embed", None), scale=0.02),
        "wg": pf.stacked_dense(E, cfg.d_model, F, ("expert", "embed", "mlp")),
        "wu": pf.stacked_dense(E, cfg.d_model, F, ("expert", "embed", "mlp")),
        "wd": pf.stacked_dense(E, F, cfg.d_model, ("expert", "mlp", "embed")),
    }


def moe_capacity(n_tokens: int, cfg) -> int:
    cap = int(np.ceil(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts))
    # round to MXU-friendly multiple
    return max(8, int(np.ceil(cap / 8)) * 8)


def moe_apply(p, x, cfg, policy: QuantPolicy):
    """x: (B,S,D) -> (y, aux_loss). Gather-based capacity dispatch:
    tokens are ranked within their expert via a stable argsort; overflow
    beyond capacity C is dropped (standard Switch semantics)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = moe_capacity(T, cfg)
    xf = x.reshape(T, D)

    logits = jnp.matmul(xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                          # (T,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)  # renorm

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1)), axis=0) / K
    aux = E * jnp.sum(me * ce)

    flat_e = topi.reshape(-1)                                     # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(E))             # (E,)
    rank_sorted = jnp.arange(T * K) - first[sorted_e]
    rank = jnp.zeros(T * K, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)              # overflow row

    tok_of = jnp.arange(T * K) // K
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].add(xf[tok_of])
    buf = buf[:-1].reshape(E, C, D)

    def expert_ffn(xb, wg, wu, wd):
        act = ACTIVATIONS[cfg.act]
        h = act(fp4_linear(xb, wg, policy=policy)) * \
            fp4_linear(xb, wu, policy=policy)
        return fp4_linear(h, wd, policy=policy)

    # obs: expert GeMMs run under vmap -- their tracers must not leak into
    # the harvest, so expert sites are not individually instrumented (§11).
    with obs.suspended():
        out_buf = jax.vmap(expert_ffn)(buf, p["wg"], p["wu"], p["wd"])  # (E,C,D)
    out_flat = out_buf.reshape(E * C, D)
    gathered = jnp.where(keep[:, None], out_flat[jnp.minimum(slot, E * C - 1)], 0.0)
    y = (gathered.reshape(T, K, D) * topv[..., None].astype(x.dtype)).sum(1)
    return y.reshape(B, S, D), aux


# ===========================================================================
# Full attention+FFN layer (the "attn" plan kind)
# ===========================================================================

def init_layer(pf: ParamFactory, cfg, layer: dict):
    p = {"ln_attn": pf.ones((cfg.d_model,), (None,)),
         "ln_ffn": pf.ones((cfg.d_model,), (None,))}
    if cfg.use_mla:
        from . import mla
        p["attn"] = mla.init_mla(pf, cfg)
    else:
        p["attn"] = init_attn(pf, cfg, layer)
    if layer.get("ffn") == "moe":
        p["ffn"] = init_moe(pf, cfg)
    else:
        p["ffn"] = init_ffn(pf, cfg, glu=cfg.act != "gelu_mlp")
    if cfg.norm_plus_one:  # gemma sandwich norms start at 0 offset (=1 mult)
        p["ln_attn"] = pf.zeros((cfg.d_model,), (None,))
        p["ln_ffn"] = pf.zeros((cfg.d_model,), (None,))
    if getattr(cfg, "sandwich_norm", False) or cfg.norm_plus_one:
        mk = pf.zeros if cfg.norm_plus_one else pf.ones
        p["ln_post_attn"] = mk((cfg.d_model,), (None,))
        p["ln_post_ffn"] = mk((cfg.d_model,), (None,))
    return p


def layer_train(p, x, positions, cfg, layer: dict, policy: QuantPolicy,
                segments=None):
    aux = jnp.float32(0.0)
    h = _norm(p["ln_attn"], x, cfg)
    if cfg.use_mla:
        from . import mla
        if segments is not None:
            raise NotImplementedError(
                "packed segment masking is not threaded through the MLA "
                "path; train packed batches with use_mla=False")
        a = mla.mla_train(p["attn"], h, positions, cfg, policy)
    else:
        a = attn_train(p["attn"], h, positions, cfg, layer, policy,
                       segments=segments)
    if "ln_post_attn" in p:
        a = _norm(p["ln_post_attn"], a, cfg)
    x = x + a
    h = _norm(p["ln_ffn"], x, cfg)
    if layer.get("ffn") == "moe":
        f, aux = moe_apply(p["ffn"], h, cfg, policy)
    else:
        f = ffn_apply(p["ffn"], h, cfg, policy)
    if "ln_post_ffn" in p:
        f = _norm(p["ln_post_ffn"], f, cfg)
    return x + f, aux


def init_layer_cache(cfg, layer: dict, batch: int, max_len: int):
    if cfg.use_mla:
        from . import mla
        return mla.init_mla_cache(cfg, batch, max_len)
    return init_attn_cache(cfg, layer, batch, max_len)


def layer_prefill(p, x, positions, cache, cfg, layer: dict,
                  policy: QuantPolicy):
    h = _norm(p["ln_attn"], x, cfg)
    if cfg.use_mla:
        from . import mla
        a, cache = mla.mla_prefill(p["attn"], h, positions, cache, cfg, policy)
    else:
        a, cache = attn_prefill(p["attn"], h, positions, cache, cfg, layer,
                                policy)
    if "ln_post_attn" in p:
        a = _norm(p["ln_post_attn"], a, cfg)
    x = x + a
    h = _norm(p["ln_ffn"], x, cfg)
    if layer.get("ffn") == "moe":
        f, _ = moe_apply(p["ffn"], h, cfg, policy)
    else:
        f = ffn_apply(p["ffn"], h, cfg, policy)
    if "ln_post_ffn" in p:
        f = _norm(p["ln_post_ffn"], f, cfg)
    return x + f, cache


def layer_prefill_paged(p, x, positions, pages, page_table, cfg, layer: dict,
                        policy: QuantPolicy):
    h = _norm(p["ln_attn"], x, cfg)
    a, pages = attn_prefill_paged(p["attn"], h, positions, pages, page_table,
                                  cfg, layer, policy)
    if "ln_post_attn" in p:
        a = _norm(p["ln_post_attn"], a, cfg)
    x = x + a
    h = _norm(p["ln_ffn"], x, cfg)
    if layer.get("ffn") == "moe":
        f, _ = moe_apply(p["ffn"], h, cfg, policy)
    else:
        f = ffn_apply(p["ffn"], h, cfg, policy)
    if "ln_post_ffn" in p:
        f = _norm(p["ln_post_ffn"], f, cfg)
    return x + f, pages


def layer_decode_paged(p, x, pages, pos, page_table, active, cfg,
                       layer: dict, policy: QuantPolicy):
    h = _norm(p["ln_attn"], x, cfg)
    a, pages = attn_decode_paged(p["attn"], h, pages, pos, page_table,
                                 active, cfg, layer, policy)
    if "ln_post_attn" in p:
        a = _norm(p["ln_post_attn"], a, cfg)
    x = x + a
    h = _norm(p["ln_ffn"], x, cfg)
    if layer.get("ffn") == "moe":
        f, _ = moe_apply(p["ffn"], h, cfg, policy)
    else:
        f = ffn_apply(p["ffn"], h, cfg, policy)
    if "ln_post_ffn" in p:
        f = _norm(p["ln_post_ffn"], f, cfg)
    return x + f, pages


def layer_decode(p, x, cache, pos, cfg, layer: dict, policy: QuantPolicy):
    h = _norm(p["ln_attn"], x, cfg)
    if cfg.use_mla:
        from . import mla
        a, cache = mla.mla_decode(p["attn"], h, cache, pos, cfg, policy)
    else:
        a, cache = attn_decode(p["attn"], h, cache, pos, cfg, layer, policy)
    if "ln_post_attn" in p:
        a = _norm(p["ln_post_attn"], a, cfg)
    x = x + a
    h = _norm(p["ln_ffn"], x, cfg)
    if layer.get("ffn") == "moe":
        f, _ = moe_apply(p["ffn"], h, cfg, policy)
    else:
        f = ffn_apply(p["ffn"], h, cfg, policy)
    if "ln_post_ffn" in p:
        f = _norm(p["ln_post_ffn"], f, cfg)
    return x + f, cache
