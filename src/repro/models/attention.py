"""Attention primitives: GQA with causal/sliding-window masking, softcap,
online-softmax KV chunking (for 32K prefill memory), and position-based
masking that unifies training, prefill, and ring-buffer decode caches.

All score/softmax math is f32 (non-GeMM ops stay high precision, paper §4.1).
The projection GeMMs live in blocks.py and go through fp4_linear.

Positions may be 1D (S,) when they are batch-uniform (training/prefill with
contiguous sequences): the mask is then a single (Sq, Skv) *boolean* shared
across the batch -- materializing a per-batch f32 bias at 4K+ costs ~1 GB
per layer and dominated the memory profile before this change.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pair_grid(q_vec, kv_vec):
    """Broadcast per-token (q, kv) vectors to a pair grid: (Sq,Skv) when
    both are 1D (batch-uniform), else (B,Sq,Skv)."""
    if q_vec.ndim == 1 and kv_vec.ndim == 1:
        return (q_vec[:, None].astype(jnp.int32),
                kv_vec[None, :].astype(jnp.int32))
    if q_vec.ndim == 1:
        q_vec = q_vec[None]
    if kv_vec.ndim == 1:
        kv_vec = kv_vec[None]
    return (q_vec[:, :, None].astype(jnp.int32),
            kv_vec[:, None, :].astype(jnp.int32))


def _mask_ok(q_pos, kv_pos, causal: bool, window: int | None,
             q_seg=None, kv_seg=None):
    """Boolean keep-mask from absolute positions (and, for packed
    sequences, segment ids). Shapes: (Sq,Skv) when all inputs are 1D,
    else (B,Sq,Skv). kv slots with position < 0 are invalid (empty cache
    slots / padding). With segments, a pair is kept only when both
    tokens carry the same id -- packed fragments never cross-attend
    (packing semantics: docs/data_format.md)."""
    qp, kp = _pair_grid(q_pos, kv_pos)
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    if q_seg is not None:
        qs, ks = _pair_grid(q_seg, kv_seg)
        ok = ok & (qs == ks)
    return ok


def _apply_mask(s, ok):
    """s: (B,Hkv,G,Sq,Skv); ok: (Sq,Skv) or (B,Sq,Skv) bool."""
    if ok.ndim == 2:
        ok = ok[None, None, None]
    else:
        ok = ok[:, None, None]
    return jnp.where(ok, s, NEG_INF)


def _scores(q, k, scale, cap):
    """q: (B,Sq,Hkv,G,Dh), k: (B,Skv,Hkv,Dh) -> (B,Hkv,G,Sq,Skv) f32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def dense_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                    softcap=None, q_seg=None, kv_seg=None):
    """Full-materialization path. q: (B,Sq,H,Dh); k,v: (B,Skv,Hkv,Dh)."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    s = _scores(qg, k, 1.0 / jnp.sqrt(Dh).astype(jnp.float32), softcap)
    s = _apply_mask(s, _mask_ok(q_pos, kv_pos, causal, window, q_seg, kv_seg))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dh)


def chunked_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                      softcap=None, kv_chunk=1024, q_seg=None, kv_seg=None):
    """Online-softmax scan over KV chunks: O(Sq * kv_chunk) live memory.

    Scan inventory (for roofline correction): trip_count = Skv/kv_chunk,
    body FLOPs ~= 4 * B * H * Sq * kv_chunk * Dh.
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if kv_seg is None:
        # kv position -1 already masks the chunk padding; a constant
        # stand-in segment keeps one scan body for both cases
        kv_seg_c = None
    else:
        kv_seg_c = kv_seg
    if Skv % kv_chunk:
        pad = kv_chunk - Skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pad_spec = ((0, pad),) if kv_pos.ndim == 1 else ((0, 0), (0, pad))
        kv_pos = jnp.pad(kv_pos, pad_spec, constant_values=-1)
        if kv_seg_c is not None:
            seg_spec = ((0, pad),) if kv_seg_c.ndim == 1 else \
                ((0, 0), (0, pad))
            kv_seg_c = jnp.pad(kv_seg_c, seg_spec, constant_values=-1)
        Skv += pad
    n = Skv // kv_chunk
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)

    ks = k.reshape(B, n, kv_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n, kv_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    if kv_pos.ndim == 1:
        ps = kv_pos.reshape(n, kv_chunk)
    else:
        ps = kv_pos.reshape(B, n, kv_chunk).transpose(1, 0, 2)
    if kv_seg_c is None:
        sgs = None
    elif kv_seg_c.ndim == 1:
        sgs = kv_seg_c.reshape(n, kv_chunk)
    else:
        sgs = kv_seg_c.reshape(B, n, kv_chunk).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        if sgs is None:
            kc, vc, pc = inp
            sc = None
        else:
            kc, vc, pc, sc = inp
        s = _scores(qg, kc, scale, softcap)                     # (B,Hkv,G,Sq,c)
        s = _apply_mask(s, _mask_ok(q_pos, pc, causal, window, q_seg, sc))
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dh), v.dtype)
    xs = (ks, vs, ps) if sgs is None else (ks, vs, ps, sgs)
    # remat: don't save per-chunk score/probability tiles for backward
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dh)


def sliding_window_attention(q, k, v, q_pos, kv_pos, *, window,
                             softcap=None):
    """Block-banded local attention for training: queries in block i attend
    to key blocks i-1 and i (band width = window = block size). Sub-quadratic:
    FLOPs ~ 4 * B * H * S * 2*window * Dh."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    W = window
    if q_pos.ndim > 1:
        q_pos = q_pos[0]
    if kv_pos.ndim > 1:
        kv_pos = kv_pos[0]
    if S % W:
        pad = W - S % W
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    Sp = q.shape[1]
    n = Sp // W
    G = H // Hkv
    scale = 1.0 / jnp.sqrt(Dh).astype(jnp.float32)

    qb = q.reshape(B, n, W, Hkv, G, Dh)
    kb = k.reshape(B, n, W, Hkv, Dh)
    vb = v.reshape(B, n, W, Hkv, Dh)
    # keys for block i: blocks [i-1, i] -> (B, n, 2W, Hkv, Dh)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([k_prev, kb], axis=2)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    qp = q_pos.reshape(n, W)
    kp = kv_pos.reshape(n, W)
    kp_prev = jnp.pad(kp, ((1, 0), (0, 0)), constant_values=-1)[:-1]
    kp2 = jnp.concatenate([kp_prev, kp], axis=1)                # (n,2W)

    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb, k2,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = (kp2[:, None, :] >= 0) & (kp2[:, None, :] <= qp[..., None]) & \
         (kp2[:, None, :] > qp[..., None] - W)                  # (n,Sq_w,2W)
    s = jnp.where(ok[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p.astype(v2.dtype), v2)
    return out.reshape(B, Sp, H, Dh)[:, :S]


def paged_attention(q, k_pages, v_pages, page_table, q_pos, seq_lens, *,
                    window=None, softcap=None):
    """Decode-time attention against a paged KV cache (DESIGN.md §13).

    Gather-by-page-table reference path: the slot's pages are gathered
    into a dense (B, P*page_size, Hkv, Dh) view and handed to
    `dense_attention` -- positions are implicit in the paged layout
    (entry j of the gathered view is absolute position j), so validity
    is just `table entry >= 0 and j < seq_len`. Single-request decode
    against a contiguous cache should keep using `dense_attention`
    directly (no gather). A Pallas gather kernel can later replace the
    materialized view without touching callers.

    q: (B,1,H,Dh); k_pages/v_pages: (N, page_size, Hkv, Dh);
    page_table: (B,P) int32, -1 = unallocated (page 0 is the reserved
    trash page and never appears in a table); q_pos: (B,1) absolute
    positions; seq_lens: (B,) valid cache entries per slot.
    """
    B, P = page_table.shape
    ps = k_pages.shape[1]
    pt = jnp.maximum(page_table, 0)
    k = k_pages[pt].reshape(B, P * ps, *k_pages.shape[2:])
    v = v_pages[pt].reshape(B, P * ps, *v_pages.shape[2:])
    kv_pos = jnp.broadcast_to(jnp.arange(P * ps, dtype=jnp.int32),
                              (B, P * ps))
    valid = jnp.repeat(page_table > 0, ps, axis=1)      # page-major order
    valid &= kv_pos < seq_lens[:, None]
    kv_pos = jnp.where(valid, kv_pos, -1)
    return dense_attention(q, k.astype(q.dtype), v.astype(q.dtype),
                           q_pos, kv_pos, causal=True, window=window,
                           softcap=softcap)


def attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
              softcap=None, kv_chunk: int | None = None, segments=None):
    """Dispatcher. Chooses the sub-quadratic/banded path for training with a
    window, the chunked path for long KV, dense otherwise.

    The banded path assumes batch-uniform positions (it reads row 0 of a
    2D position array), so it is only taken for 1D positions -- ragged
    left-padded prefill batches (per-row positions, serve scheduler)
    fall through to the chunked/dense paths, whose masks are per-row.

    `segments` are per-token segment ids for packed self-attention
    ((B,S) or (S,), 0 = padding): pairs from different segments are
    masked. The banded path carries no segment plumbing, so packed
    batches always take the chunked/dense paths."""
    Sq, Skv = q.shape[1], k.shape[1]
    if (window is not None and Sq == Skv and Sq > window
            and q_pos.ndim == 1 and kv_pos.ndim == 1 and segments is None):
        return sliding_window_attention(q, k, v, q_pos, kv_pos, window=window,
                                        softcap=softcap)
    if kv_chunk is not None and Skv > 2 * kv_chunk and Sq > 1:
        return chunked_attention(q, k, v, q_pos, kv_pos, causal=causal,
                                 window=window, softcap=softcap,
                                 kv_chunk=kv_chunk, q_seg=segments,
                                 kv_seg=segments)
    return dense_attention(q, k, v, q_pos, kv_pos, causal=causal,
                           window=window, softcap=softcap, q_seg=segments,
                           kv_seg=segments)
