"""Layer stacking for scan-over-layers execution.

The dry-run compiles 24-81-layer models on a single-core CPU host; unrolled
layers make XLA compile time O(L). `find_group` detects the smallest
repeating unit in a layer plan (1 for homogeneous stacks, 2 for gemma2's
local/global alternation, 6 for gemma3's 5:1 and zamba2's shared-block
cadence); params/caches for the repeated group are stacked with a leading
(n_groups,) dim and executed with `lax.scan`. Any non-repeating tail is
executed unrolled.

Accounting note (EXPERIMENTS.md): XLA cost_analysis counts a while body
once; analysis/flops.py adds (1 - 1/n_groups) of the scanned layers'
analytic FLOPs back. Collectives are multiplied by the parsed trip count
(analysis/hlo.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import Boxed, is_boxed


def find_group(plan: list[dict]) -> tuple[int, int]:
    """Returns (group_size, n_groups) with n_groups >= 2, maximizing
    coverage; (0, 0) if no useful repetition."""
    L = len(plan)
    for g in range(1, L // 2 + 1):
        n = L // g
        if n < 2:
            break
        if all(plan[i] == plan[i % g] for i in range(n * g)):
            return g, n
    return 0, 0


def stack_boxed_trees(trees: list):
    """Stack a list of identical-structure Boxed trees along a new leading
    'layer' axis."""
    def stack(*leaves):
        vals = [l.value for l in leaves]
        axes = ("layer",) + tuple(leaves[0].axes)
        return Boxed(jnp.stack(vals), axes)

    return jax.tree.map(stack, *trees, is_leaf=is_boxed)


def stack_trees(trees: list):
    """Stack plain array trees (caches) along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
