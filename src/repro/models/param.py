"""Parameter trees with logical sharding axes.

Every parameter is created through `ParamFactory`, which records a parallel
tree of *logical axis names* (e.g. ("embed", "mlp")). `dist/sharding.py`
maps logical names onto mesh axes; models never mention mesh axes directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Boxed:
    """A leaf holding (value, logical_axes). Trees of Boxed are split into a
    value tree and an axes tree with `split_tree`."""
    value: Any
    axes: tuple[str | None, ...]


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def split_tree(tree):
    """tree of Boxed -> (params tree, logical-axes tree)."""
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return params, axes


class ParamFactory:
    """Splittable PRNG + initializers that attach logical axes.

    Initialization follows standard LLM practice: truncated-normal fan-in
    scaling for projections, normal(0.02-ish) embeddings, zeros for biases.
    Params are created in float32 (master precision); the forward pass casts
    to the policy compute dtype.
    """

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, in_dim: int, out_dim: int, axes: tuple[str | None, str | None],
              scale: float | None = None) -> Boxed:
        scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
        w = jax.random.truncated_normal(
            self._next(), -3, 3, (in_dim, out_dim), self.dtype) * scale
        return Boxed(w, axes)

    def stacked_dense(self, stack: int, in_dim: int, out_dim: int,
                      axes: tuple[str | None, str | None, str | None],
                      scale: float | None = None) -> Boxed:
        """(stack, in, out) -- e.g. per-expert weights with axes[0]='expert'."""
        scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
        w = jax.random.truncated_normal(
            self._next(), -3, 3, (stack, in_dim, out_dim), self.dtype) * scale
        return Boxed(w, axes)

    def embedding(self, vocab: int, dim: int,
                  axes: tuple[str | None, str | None] = ("vocab", "embed"),
                  scale: float = 0.02) -> Boxed:
        w = jax.random.normal(self._next(), (vocab, dim), self.dtype) * scale
        return Boxed(w, axes)

    def zeros(self, shape: tuple[int, ...], axes: tuple[str | None, ...]) -> Boxed:
        return Boxed(jnp.zeros(shape, self.dtype), axes)

    def ones(self, shape: tuple[int, ...], axes: tuple[str | None, ...]) -> Boxed:
        return Boxed(jnp.ones(shape, self.dtype), axes)

    def const(self, value: np.ndarray | jnp.ndarray,
              axes: tuple[str | None, ...]) -> Boxed:
        return Boxed(jnp.asarray(value, self.dtype), axes)
