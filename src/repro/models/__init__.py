"""Model zoo: a single CausalLM assembly + WhisperLM enc-dec, covering the
10 assigned architecture families. `build_model` is the factory used by the
launcher, smoke tests, and the dry-run."""
from __future__ import annotations

from repro.core.policy import QuantPolicy

from .encdec import WhisperLM
from .transformer import CausalLM


def build_model(cfg, policy: QuantPolicy, act_constraint=None):
    if cfg.family == "encdec" or cfg.enc_layers > 0:
        return WhisperLM(cfg, policy, act_constraint)
    return CausalLM(cfg, policy, act_constraint)


__all__ = ["CausalLM", "WhisperLM", "build_model"]
