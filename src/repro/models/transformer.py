"""CausalLM: decoder-only assembly covering dense / MoE / SSM / hybrid /
RWKV / embedding-frontend (VLM) families from a single layer plan.

Pure-functional API:
    m = CausalLM(cfg, policy)
    params, specs = m.init(key)
    loss, metrics  = m.loss(params, batch)
    cache          = m.init_cache(batch_size, max_len)
    logits, cache  = m.decode_step(params, cache, tokens, pos)
    logits, cache  = m.prefill(params, batch, cache)

Execution modes:
  * cfg.scan_layers=False -- every layer unrolled (exact per-layer HLO).
  * cfg.scan_layers=True  -- the repeating layer group (stacking.find_group)
    is stacked along a leading (n_groups,) axis and run with lax.scan;
    the non-repeating tail stays unrolled. Params/caches change structure
    accordingly ("stack"/"rest" instead of a flat list). The dry-run uses
    this mode (compile time O(group) instead of O(L)).

`act_constraint` is injected by the distribution layer to apply
sequence-parallel sharding constraints between layers without the model
knowing mesh axis names.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from . import blocks, rwkv, ssm, stacking
from .layers import causal_lm_loss, embed_lookup, rms_norm
from .param import Boxed, ParamFactory, split_tree

_SHARED_LAYER = {"kind": "attn", "window": None, "ffn": "dense"}


def _remat(cfg):
    """jax.checkpoint wrapper honoring cfg.remat_policy ('dots' trades
    activation memory for ~25% less backward recompute -- §Perf)."""
    if getattr(cfg, "remat_policy", "full") == "dots":
        import functools
        return functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint


class CausalLM:
    def __init__(self, cfg, policy: QuantPolicy,
                 act_constraint: Callable | None = None):
        self.cfg = cfg
        self.policy = policy
        self.plan = cfg.layer_plan()
        self.constrain = act_constraint or (lambda x: x)
        if getattr(cfg, "scan_layers", False):
            self.group_size, self.n_groups = stacking.find_group(self.plan)
        else:
            self.group_size, self.n_groups = 0, 0

    @property
    def stacked(self) -> bool:
        return self.n_groups >= 2

    @property
    def _tail_start(self) -> int:
        return self.group_size * self.n_groups if self.stacked else 0

    def _shared_layer(self):
        return dict(_SHARED_LAYER, rope_theta=self.cfg.rope_theta)

    # ------------------------------------------------------------------ init
    def _init_one_layer(self, pf, layer):
        cfg = self.cfg
        kind = layer["kind"]
        if kind in ("attn", "mla"):
            return blocks.init_layer(pf, cfg, layer)
        if kind == "ssm":
            return ssm.init_ssm(pf, cfg)
        if kind == "rwkv":
            return rwkv.init_rwkv(pf, cfg)
        if kind == "shared_attn":
            return {"_placeholder": pf.zeros((1,), (None,))}
        raise ValueError(kind)

    def init(self, key: jax.Array):
        cfg = self.cfg
        pf = ParamFactory(key)
        tree: dict[str, Any] = {
            "embed": pf.embedding(cfg.vocab_size, cfg.d_model),
            "ln_f": (pf.zeros if cfg.norm_plus_one else pf.ones)(
                (cfg.d_model,), (None,)),
        }
        if not cfg.tie_embeddings:
            tree["head"] = pf.dense(cfg.d_model, cfg.vocab_size,
                                    ("embed", "vocab"))
        per_layer = [self._init_one_layer(pf, l) for l in self.plan]
        if self.stacked:
            g, n = self.group_size, self.n_groups
            tree["stack"] = [
                stacking.stack_boxed_trees([per_layer[k * g + p]
                                            for k in range(n)])
                for p in range(g)
            ]
            tree["rest"] = per_layer[self._tail_start:]
        else:
            tree["layers"] = per_layer
        if any(l["kind"] == "shared_attn" for l in self.plan):
            tree["shared"] = blocks.init_layer(pf, cfg, self._shared_layer())
        return split_tree(tree)

    # ----------------------------------------------------------------- embed
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "embeddings":
            x = batch["embeds"].astype(self.policy.compute_dtype)
        else:
            x = embed_lookup(params["embed"], batch["tokens"],
                             self.policy.compute_dtype,
                             onehot=self.cfg.embed_onehot)
        if cfg.embed_scale_sqrt_d:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x

    def _head_w(self, params):
        if "head" in params:
            return params["head"].astype(self.policy.compute_dtype)
        return params["embed"].T.astype(self.policy.compute_dtype)

    # ------------------------------------------------------------ layer exec
    def _apply_train(self, p, shared_p, x, positions, layer, segments=None):
        cfg, policy = self.cfg, self.policy
        kind = layer["kind"]
        if segments is not None and kind in ("ssm", "rwkv"):
            # recurrent state carries across the whole row -- packing
            # isolation is an attention-mask concept and doesn't apply
            raise NotImplementedError(
                f"packed segment masking unsupported for {kind!r} layers")
        if kind in ("attn", "mla"):
            y, aux = blocks.layer_train(p, x, positions, cfg, layer, policy,
                                        segments=segments)
        elif kind == "ssm":
            y, aux = ssm.ssm_train(p, x, positions, cfg, layer, policy), 0.0
        elif kind == "rwkv":
            y, aux = rwkv.rwkv_train(p, x, positions, cfg, layer, policy), 0.0
        elif kind == "shared_attn":
            y, aux = blocks.layer_train(shared_p, x, positions, cfg,
                                        self._shared_layer(), policy,
                                        segments=segments)
        return self.constrain(y), jnp.float32(aux)

    def backbone(self, params, x, positions, segments=None):
        """Runs all layers; returns (hidden, total_aux_loss)."""
        cfg = self.cfg
        shared_p = params.get("shared")
        aux0 = jnp.float32(0.0)

        if self.stacked:
            group_plan = self.plan[:self.group_size]

            def group_body(carry, stacked_slice):
                x, aux = carry
                for p_idx, layer in enumerate(group_plan):
                    # nested remat: group-level remat alone lets XLA keep all
                    # in-group layer recomputations live during backward
                    def one(p, sp, x, positions, _layer=layer):
                        return self._apply_train(p, sp, x, positions, _layer,
                                                 segments=segments)
                    if cfg.remat and len(group_plan) > 1:
                        one = _remat(cfg)(one)
                    x, a = one(stacked_slice[p_idx], shared_p, x, positions)
                    aux = aux + a
                return (x, aux), None

            body = _remat(cfg)(group_body) if cfg.remat else group_body
            # obs: scan-body tracers must not leak into the harvest --
            # stacked layers are not individually instrumented (§11).
            with obs.suspended():
                (x, aux), _ = jax.lax.scan(body, (x, aux0), params["stack"])
            tail_params = params["rest"]
            tail_plan = self.plan[self._tail_start:]
        else:
            aux = aux0
            tail_params = params["layers"]
            tail_plan = self.plan

        for i, (p, layer) in enumerate(zip(tail_params, tail_plan)):
            def fn(p, shared_p, x, positions, _layer=layer):
                return self._apply_train(p, shared_p, x, positions, _layer,
                                         segments=segments)
            if cfg.remat:
                # remat regions are traced at an inner level; per-layer
                # telemetry requires remat=False (the obs configuration).
                fn = _remat(cfg)(obs.suppress(fn))
            with obs.scope(f"L{self._tail_start + i}"):
                x, a = fn(p, shared_p, x, positions)
            aux = aux + a
        return rms_norm(x, params["ln_f"], plus_one=cfg.norm_plus_one), aux

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch):
        """Packed batches additionally carry (B,S) `segment_ids` (0 = pad,
        data/packing.py): attention is then segment-isolated and the
        cross-fragment label predictions are masked via `loss_mask`."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        B, S = x.shape[:2]
        positions = batch.get("positions",
                              jnp.arange(S, dtype=jnp.int32))
        segments = batch.get("segment_ids")
        # Quant-health collection (repro.obs): records made while tracing
        # the backbone are harvested here, inside the same trace, and flow
        # out through the aux metrics dict (survives jit / value_and_grad).
        with obs.collect(enabled=self.policy.obs_metrics) as col:
            x, aux = self.backbone(params, x, positions, segments=segments)
        head_w = self._head_w(params)
        tokens = batch["labels"] if cfg.frontend == "embeddings" else \
            batch["tokens"]
        lm = causal_lm_loss(x, head_w, tokens, chunk=cfg.loss_chunk,
                            logit_softcap=cfg.final_softcap,
                            loss_mask=batch.get("loss_mask"))
        loss = lm + 0.01 * aux
        metrics = {"lm_loss": lm, "aux_loss": aux}
        if col is not None:
            metrics["obs"] = col.harvest()
        return loss, metrics

    # ----------------------------------------------------------------- serve
    def _init_one_cache(self, layer, batch_size, max_len):
        cfg = self.cfg
        kind = layer["kind"]
        if kind in ("attn", "mla"):
            return blocks.init_layer_cache(cfg, layer, batch_size, max_len)
        if kind == "shared_attn":
            return blocks.init_layer_cache(cfg, self._shared_layer(),
                                           batch_size, max_len)
        if kind == "ssm":
            return ssm.init_ssm_cache(cfg, layer, batch_size, max_len)
        return rwkv.init_rwkv_cache(cfg, layer, batch_size, max_len)

    def init_cache(self, batch_size: int, max_len: int):
        per_layer = [self._init_one_cache(l, batch_size, max_len)
                     for l in self.plan]
        if self.stacked:
            g, n = self.group_size, self.n_groups
            return {
                "stack": [stacking.stack_trees([per_layer[k * g + p]
                                                for k in range(n)])
                          for p in range(g)],
                "rest": per_layer[self._tail_start:],
            }
        return {"layers": per_layer}

    def _apply_decode(self, p, shared_p, x, c, pos, layer):
        cfg, policy = self.cfg, self.policy
        kind = layer["kind"]
        if kind in ("attn", "mla"):
            x, c = blocks.layer_decode(p, x, c, pos, cfg, layer, policy)
        elif kind == "shared_attn":
            x, c = blocks.layer_decode(shared_p, x, c, pos, cfg,
                                       self._shared_layer(), policy)
        elif kind == "ssm":
            x, c = ssm.ssm_decode(p, x, c, pos, cfg, layer, policy)
        else:
            x, c = rwkv.rwkv_decode(p, x, c, pos, cfg, layer, policy)
        return self.constrain(x), c

    def _apply_prefill(self, p, shared_p, x, c, positions, layer):
        cfg, policy = self.cfg, self.policy
        kind = layer["kind"]
        if kind in ("attn", "mla"):
            x, c = blocks.layer_prefill(p, x, positions, c, cfg, layer, policy)
        elif kind == "shared_attn":
            x, c = blocks.layer_prefill(shared_p, x, positions, c, cfg,
                                        self._shared_layer(), policy)
        elif kind == "ssm":
            x, c = ssm.ssm_prefill(p, x, positions, c, cfg, layer, policy)
        else:
            x, c = rwkv.rwkv_prefill(p, x, positions, c, cfg, layer, policy)
        return self.constrain(x), c

    def _run_serve(self, params, cache, x, apply_fn):
        """Shared scan/unroll plumbing for decode_step and prefill.
        apply_fn(p, shared_p, x, c, layer) closes over pos/positions."""
        shared_p = params.get("shared")
        if self.stacked:
            group_plan = self.plan[:self.group_size]

            def step(x, inp):
                p_slice, c_slice = inp
                new_c = []
                for p_idx, layer in enumerate(group_plan):
                    x, c = apply_fn(p_slice[p_idx], shared_p, x,
                                    c_slice[p_idx], layer)
                    new_c.append(c)
                return x, new_c

            with obs.suspended():  # scan-body tracers must not escape
                x, new_stack = jax.lax.scan(step, x,
                                            (params["stack"], cache["stack"]))
            new_rest = []
            for i, (p, c, layer) in enumerate(zip(params["rest"],
                                                  cache["rest"],
                                                  self.plan[self._tail_start:])):
                with obs.scope(f"L{self._tail_start + i}"):
                    x, c = apply_fn(p, shared_p, x, c, layer)
                new_rest.append(c)
            return x, {"stack": new_stack, "rest": new_rest}
        new_layers = []
        for i, (p, c, layer) in enumerate(zip(params["layers"],
                                              cache["layers"], self.plan)):
            with obs.scope(f"L{i}"):
                x, c = apply_fn(p, shared_p, x, c, layer)
            new_layers.append(c)
        return x, {"layers": new_layers}

    # ---------------------------------------------------------- paged serve
    @property
    def supports_paged(self) -> bool:
        """Paged decode covers attention-only plans (dense/MoE FFNs; no
        MLA latent caches, no SSM/RWKV state carries)."""
        return (not self.cfg.use_mla
                and all(l["kind"] == "attn" for l in self.plan))

    def _check_paged(self):
        if not self.supports_paged:
            raise NotImplementedError(
                "paged KV serving needs an attention-only layer plan "
                f"(got kinds {sorted({l['kind'] for l in self.plan})}, "
                f"use_mla={self.cfg.use_mla})")

    def init_paged_cache(self, n_pages: int, page_size: int):
        """Per-layer page pools (k_pages/v_pages); the page table and slot
        lengths live host-side in serve/paged_cache.py. Every layer gets
        its own pool of `n_pages` pages (page 0 reserved as trash)."""
        self._check_paged()
        per_layer = [blocks.init_attn_pages(self.cfg, n_pages, page_size)
                     for _ in self.plan]
        if self.stacked:
            g, n = self.group_size, self.n_groups
            return {
                "stack": [stacking.stack_trees([per_layer[k * g + p]
                                                for k in range(n)])
                          for p in range(g)],
                "rest": per_layer[self._tail_start:],
            }
        return {"layers": per_layer}

    def decode_step_paged(self, params, pages, tokens, pos, page_table,
                          active):
        """One token per slot against the paged cache. tokens: (B,1) int32;
        pos: (B,) int32 per-slot write position; page_table: (B,P) int32;
        active: (B,) bool. Returns (logits (B,V), new_pages)."""
        self._check_paged()
        cfg, policy = self.cfg, self.policy
        x = embed_lookup(params["embed"], tokens, policy.compute_dtype)
        if cfg.embed_scale_sqrt_d:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        apply_fn = lambda p, sp, x, c, layer: blocks.layer_decode_paged(
            p, x, c, pos, page_table, active, cfg, layer, policy)
        x, new_pages = self._run_serve(params, pages, x, apply_fn)
        x = rms_norm(x, params["ln_f"], plus_one=cfg.norm_plus_one)
        logits = jnp.matmul(x[:, 0], self._head_w(params),
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, new_pages

    def prefill_paged(self, params, batch, pages, page_table):
        """Prompt processing into the paged cache. batch carries (B,S)
        tokens plus (B,S) `positions` (pads < 0 for left-padded ragged
        prompts). Returns (last-position logits (B,V), new_pages)."""
        self._check_paged()
        cfg = self.cfg
        x = self._embed_in(params, batch)
        B, S = x.shape[:2]
        positions = batch.get("positions",
                              jnp.broadcast_to(
                                  jnp.arange(S, dtype=jnp.int32), (B, S)))
        apply_fn = lambda p, sp, x, c, layer: blocks.layer_prefill_paged(
            p, x, positions, c, page_table, cfg, layer, self.policy)
        x, new_pages = self._run_serve(params, pages, x, apply_fn)
        x = rms_norm(x, params["ln_f"], plus_one=cfg.norm_plus_one)
        logits = jnp.matmul(x[:, -1], self._head_w(params),
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, new_pages

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,1) int32 (or embeds (B,1,D)); pos: scalar int32, or
        (B,) int32 per-slot positions (attention-only plans).
        Returns (logits (B,V), new_cache)."""
        cfg, policy = self.cfg, self.policy
        if cfg.frontend == "embeddings" and tokens.ndim == 3:
            x = tokens.astype(policy.compute_dtype)
        else:
            x = embed_lookup(params["embed"], tokens, policy.compute_dtype)
        if cfg.embed_scale_sqrt_d:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

        apply_fn = lambda p, sp, x, c, layer: self._apply_decode(
            p, sp, x, c, pos, layer)
        x, new_cache = self._run_serve(params, cache, x, apply_fn)
        x = rms_norm(x, params["ln_f"], plus_one=cfg.norm_plus_one)
        logits = jnp.matmul(x[:, 0], self._head_w(params),
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, new_cache

    def prefill(self, params, batch, cache):
        """Parallel prompt processing + cache fill.
        Returns (last-position logits (B,V), filled cache)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        B, S = x.shape[:2]
        positions = batch.get("positions", jnp.arange(S, dtype=jnp.int32))

        def apply_fn(p, sp, x, c, layer):
            def fn(p, sp, x, c, _layer=layer):
                return self._apply_prefill(p, sp, x, c, positions, _layer)
            if cfg.remat:
                fn = jax.checkpoint(obs.suppress(fn))
            return fn(p, sp, x, c)

        x, new_cache = self._run_serve(params, cache, x, apply_fn)
        x = rms_norm(x, params["ln_f"], plus_one=cfg.norm_plus_one)
        logits = jnp.matmul(x[:, -1], self._head_w(params),
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, new_cache
