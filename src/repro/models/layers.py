"""Shared model layers: norms, RoPE, activations, chunked cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in f32 accumulation (non-GeMM op => high precision per §4.1).
    `plus_one` follows the Gemma convention (weight stored as offset)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (xf * w).astype(dt)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


# --- rotary position embeddings ---------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, Dh); positions: (S,) batch-uniform or (B, S) int32.
    Rotates pairs (even, odd halves convention, LLaMA-style)."""
    if positions.ndim == 1:
        positions = positions[None]                            # (1, S)
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- embedding + loss --------------------------------------------------------

def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 compute_dtype=jnp.bfloat16, onehot: bool = False) -> jnp.ndarray:
    """Embedding lookup.

    onehot=True: Megatron-style vocab-parallel lookup as a one-hot matmul.
    With the table sharded over 'model' on the vocab dim, GSPMD turns the
    contraction into local-partial + psum of the (tokens, D) OUTPUT --
    ~vocab/tokens x less communication than all-gathering the table, at
    negligible per-chip MXU cost (the gemma3 hillclimb move, EXPERIMENTS.md
    §Perf)."""
    if onehot:
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=compute_dtype)
        return jnp.matmul(oh, table.astype(compute_dtype))
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def chunked_softmax_xent(x: jnp.ndarray, head_w: jnp.ndarray,
                         labels: jnp.ndarray, mask: jnp.ndarray | None = None,
                         chunk: int = 512,
                         logit_softcap: float | None = None) -> jnp.ndarray:
    """Cross-entropy without materializing full (B,S,V) logits.

    x: (B,S,D) final hidden; head_w: (D,V) (often emb.T); labels: (B,S);
    mask: (B,S) 1.0 = contributes. Scans over sequence chunks; each chunk's
    logits are (B,chunk,V) and die inside the scan body.
    """
    B, S, D = x.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n_chunks = max(1, S // chunk)
    if S % chunk:
        # pad to a multiple; padded positions are masked out
        pad = n_chunks * chunk + chunk - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n_chunks += 1
    xs = x.reshape(B, n_chunks, -1, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n_chunks, -1).transpose(1, 0, 2)
    ms = mask.reshape(B, n_chunks, -1).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = jnp.matmul(xc, head_w, preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            logits = softcap(logits, logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    carry = (jnp.float32(0), jnp.float32(0))
    if n_chunks <= 16:
        # Unrolled: exact FLOP accounting in the dry-run (XLA counts while
        # bodies once) at negligible HLO-size cost.
        for i in range(n_chunks):
            carry, _ = body(carry, (xs[i], ls[i], ms[i]))
    else:
        carry, _ = jax.lax.scan(body, carry, (xs, ls, ms))
    tot, cnt = carry
    return tot / jnp.maximum(cnt, 1.0)


def causal_lm_loss(x: jnp.ndarray, head_w: jnp.ndarray, tokens: jnp.ndarray,
                   *, pad_id: int = 0, chunk: int = 512,
                   logit_softcap: float | None = None,
                   loss_mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Next-token prediction: positions 0..S-2 predict tokens 1..S-1."""
    labels = tokens[:, 1:]
    mask = (labels != pad_id).astype(jnp.float32)
    if loss_mask is not None:
        mask = mask * loss_mask[:, 1:].astype(jnp.float32)
    return chunked_softmax_xent(x[:, :-1], head_w, labels, mask, chunk,
                                logit_softcap)
