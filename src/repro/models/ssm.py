"""Mamba2 (SSD) block -- chunked state-space dual form, matmul-dominant.

TPU adaptation: the SSD chunked algorithm is MXU-friendly (within-chunk
quadratic terms are batched GeMMs); the cross-chunk recurrence is a
`lax.scan` over chunks whose body is also GeMM-heavy. All decays are
computed as exp of *non-positive* cumulative sums, so every exponential is
bounded by 1 (numerically safe in bf16/f32).

Projections (in/out/gate) are GeMMs -> fp4_linear applies. The recurrence
itself is not a GeMM against weights -> stays high precision (the paper's
non-GeMM rule; noted in DESIGN.md §5 for zamba2/rwkv6).

Scan inventory: trip_count = S / ssm_chunk; body FLOPs dominated by
(L x L) score GeMMs and (L x N x P) state GeMMs -- reported analytically by
configs' flops model for the roofline correction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from .blocks import CACHE_DTYPES
from .layers import rms_norm
from .param import ParamFactory

CONV_K = 4  # mamba2 short causal depthwise conv


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(pf: ParamFactory, cfg):
    d_inner, H, P, N = _dims(cfg)
    return {
        "ln": pf.ones((cfg.d_model,), (None,)),
        "in_zx": pf.dense(cfg.d_model, 2 * d_inner, ("embed", "mlp")),
        "in_bcdt": pf.dense(cfg.d_model, 2 * N + H, ("embed", None)),
        "conv_x": pf.zeros((d_inner, CONV_K), ("mlp", None)),
        "conv_b": pf.zeros((N, CONV_K), (None, None)),
        "conv_c": pf.zeros((N, CONV_K), (None, None)),
        "a_log": pf.const(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
                          (None,)),
        "d_skip": pf.ones((H,), (None,)),
        "dt_bias": pf.zeros((H,), (None,)),
        "gate_ln": pf.ones((d_inner,), ("mlp",)),
        "out": pf.dense(d_inner, cfg.d_model, ("mlp", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv, kernel CONV_K. x: (B,S,C), w: (C,K)."""
    B, S, C = x.shape
    xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(CONV_K):
        out = out + xp[:, i:i + S] * w[:, i]
    return out


def _proj_split(p, h, cfg, policy):
    d_inner, H, P, N = _dims(cfg)
    zx = fp4_linear(h, p["in_zx"], policy=policy)
    z, xs = jnp.split(zx, 2, axis=-1)
    bcdt = fp4_linear(h, p["in_bcdt"], policy=policy)
    b, c, dt = jnp.split(bcdt, [N, 2 * N], axis=-1)
    return z, xs, b, c, dt


def ssm_train(p, x, positions, cfg, layer, policy: QuantPolicy):
    y, _ = _ssd_block(p, x, cfg, policy)
    return y


def ssm_prefill(p, x, positions, cache, cfg, layer, policy: QuantPolicy):
    """Parallel prompt processing; returns the recurrent + conv state."""
    y, st = _ssd_block(p, x, cfg, policy)
    return y, st


def _ssd_block(p, x, cfg, policy: QuantPolicy):
    """Full SSD block: norm -> proj -> conv -> chunked SSD -> gate -> out.
    Returns (residual output, cache-state dict)."""
    B, S, D = x.shape
    d_inner, H, P, N = _dims(cfg)
    L = _pick_chunk(S, cfg.ssm_chunk)

    h = rms_norm(x, p["ln"], plus_one=cfg.norm_plus_one)
    z, xs_raw, b_raw, c_raw, dt = _proj_split(p, h, cfg, policy)
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_x"]))
    b = jax.nn.silu(_causal_conv(b_raw, p["conv_b"]))
    c = jax.nn.silu(_causal_conv(c_raw, p["conv_c"]))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                      # (H,)
    xh = xs.reshape(B, S, H, P)
    da = dt * a                                                       # (B,S,H) <= 0

    nc = S // L
    xc = xh.reshape(B, nc, L, H, P).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, nc, L, N).transpose(1, 0, 2, 3)
    cc = c.reshape(B, nc, L, N).transpose(1, 0, 2, 3)
    dac = da.reshape(B, nc, L, H).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nc, L, H).transpose(1, 0, 2, 3)

    mask = jnp.tril(jnp.ones((L, L), jnp.float32))

    def chunk_body(state, inp):
        xcb, bcb, ccb, dab, dtb = inp          # (B,L,...)
        cum = jnp.cumsum(dab, axis=1)          # (B,L,H), non-positive & decreasing
        # intra-chunk: Y[i] = sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
        scores = jnp.einsum("bin,bjn->bij", ccb, bcb,
                            preferred_element_type=jnp.float32)
        # mask the exponent, not the product: for i<j the difference is
        # positive and exp overflows to inf (inf*0 = NaN).
        diff = cum[:, :, None, :] - cum[:, None, :, :]                # (B,L,L,H)
        decay = jnp.exp(jnp.where(mask[None, :, :, None] > 0, diff, -jnp.inf))
        m = scores[..., None] * decay
        m = m * dtb[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", m.astype(xcb.dtype), xcb)
        # inter-chunk: Y[i] += (C_i . state) * exp(cum_i)
        y = y + jnp.einsum("bin,bhpn->bihp", ccb, state).astype(y.dtype) * \
            jnp.exp(cum)[..., None].astype(y.dtype)
        # state update: state' = state*exp(cum_L) + sum_j exp(cum_L - cum_j) dt_j B_j x_j
        last = cum[:, -1]                                              # (B,H)
        w = (dtb * jnp.exp(last[:, None, :] - cum)).astype(xcb.dtype)  # (B,L,H)
        new_state = state * jnp.exp(last)[:, :, None, None] + \
            jnp.einsum("blh,bln,blhp->bhpn", w, bcb.astype(xcb.dtype), xcb
                       ).astype(jnp.float32)
        return new_state, y

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    # remat the chunk body: the (B,L,L,H) decay tensor would otherwise be
    # saved per chunk for backward (O(nc * L^2 * H) residual memory).
    state, ys = jax.lax.scan(jax.checkpoint(chunk_body), state0,
                             (xc, bc, cc, dac, dtc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"])
    out = x + fp4_linear(y, p["out"], policy=policy)
    # conv tails: last K-1 *pre-activation* conv inputs (the raw projections)
    st = {
        "state": state,
        "conv_x": _tail(xs_raw, S),
        "conv_b": _tail(b_raw, S),
        "conv_c": _tail(c_raw, S),
    }
    return out, st


def _pick_chunk(S: int, max_chunk: int) -> int:
    """Largest divisor of S that is <= max_chunk (exact chunking keeps the
    carried state correct for prefill)."""
    L = min(max_chunk, S)
    while S % L:
        L -= 1
    return L


def _tail(t, S):
    """Last CONV_K-1 positions (zero-left-padded if S < K-1), f32."""
    k = CONV_K - 1
    t = t.astype(jnp.float32)
    if S >= k:
        return t[:, S - k:S]
    return jnp.pad(t, ((0, 0), (k - S, 0), (0, 0)))


def init_ssm_cache(cfg, layer, batch: int, max_len: int):
    d_inner, H, P, N = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, CONV_K - 1, d_inner), jnp.float32),
        "conv_b": jnp.zeros((batch, CONV_K - 1, N), jnp.float32),
        "conv_c": jnp.zeros((batch, CONV_K - 1, N), jnp.float32),
    }


def _conv_step(xc, w, buf):
    """Single-token causal conv. xc: (B,1,C), buf: (B,K-1,C)."""
    window = jnp.concatenate([buf, xc.astype(buf.dtype)], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w)[:, None, :]
    return y.astype(xc.dtype), window[:, 1:]


def ssm_decode(p, x, cache, pos, cfg, layer, policy: QuantPolicy):
    B = x.shape[0]
    d_inner, H, P, N = _dims(cfg)
    h = rms_norm(x, p["ln"], plus_one=cfg.norm_plus_one)
    z, xs, b, c, dt = _proj_split(p, h, cfg, policy)
    xs, conv_x = _conv_step(xs, p["conv_x"], cache["conv_x"])
    b, conv_b = _conv_step(b, p["conv_b"], cache["conv_b"])
    c, conv_c = _conv_step(c, p["conv_c"], cache["conv_c"])
    xs, b, c = jax.nn.silu(xs), jax.nn.silu(b), jax.nn.silu(c)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                               # (B,H)
    xh = xs[:, 0].reshape(B, H, P).astype(jnp.float32)
    state = cache["state"] * da[:, :, None, None] + \
        jnp.einsum("bh,bn,bhp->bhpn", dt, b[:, 0].astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_ln"])
    out = x + fp4_linear(y, p["out"], policy=policy)
    return out, {"state": state, "conv_x": conv_x, "conv_b": conv_b,
                 "conv_c": conv_c}
