"""Whisper-style encoder-decoder LM (audio frontend stubbed per assignment:
`enc_embeds` are precomputed conv-frontend frame embeddings).

Encoder: bidirectional MHA + GELU MLP, sinusoidal positions, LayerNorm.
Decoder: causal self-attn + cross-attn + GELU MLP, learned positions.
All GeMMs (QKV/O, cross-attn projections, MLP) run through fp4_linear.

Decode cache: per-decoder-layer self-attn ring + cross-attn K/V computed
once from the encoder memory at prefill.

cfg.scan_layers stacks the homogeneous encoder and decoder layer stacks
(see transformer.py for the accounting rationale).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from . import attention as attn_mod
from . import stacking
from .blocks import CACHE_DTYPES
from .layers import layer_norm
from .param import ParamFactory, split_tree


def _sinusoid(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, dim, 2) / dim)
    emb = np.zeros((length, dim), np.float32)
    emb[:, 0::2] = np.sin(pos * div)
    emb[:, 1::2] = np.cos(pos * div)
    return emb


class WhisperLM:
    MAX_POS = 65536  # learned decoder positions table (assignment stresses 32k)

    def __init__(self, cfg, policy: QuantPolicy, act_constraint=None):
        self.cfg = cfg
        self.policy = policy
        self.constrain = act_constraint or (lambda x: x)
        self.stacked = bool(getattr(cfg, "scan_layers", False))

    # ---------------------------------------------------------------- init
    def _init_mha(self, pf):
        d = self.cfg.d_model
        return {
            "wq": pf.dense(d, d, ("embed", "heads")),
            "bq": pf.zeros((d,), ("heads",)),
            "wk": pf.dense(d, d, ("embed", "heads")),
            "wv": pf.dense(d, d, ("embed", "heads")),
            "bv": pf.zeros((d,), ("heads",)),
            "wo": pf.dense(d, d, ("heads", "embed")),
            "bo": pf.zeros((d,), (None,)),
        }

    def _init_mlp(self, pf):
        cfg = self.cfg
        return {
            "wu": pf.dense(cfg.d_model, cfg.d_ff, ("embed", "mlp")),
            "bu": pf.zeros((cfg.d_ff,), ("mlp",)),
            "wd": pf.dense(cfg.d_ff, cfg.d_model, ("mlp", "embed")),
            "bd": pf.zeros((cfg.d_model,), (None,)),
        }

    def _init_ln(self, pf):
        return {"w": pf.ones((self.cfg.d_model,), (None,)),
                "b": pf.zeros((self.cfg.d_model,), (None,))}

    def init(self, key):
        cfg = self.cfg
        pf = ParamFactory(key)
        enc_layers = [{"ln1": self._init_ln(pf), "attn": self._init_mha(pf),
                       "ln2": self._init_ln(pf), "mlp": self._init_mlp(pf)}
                      for _ in range(cfg.enc_layers)]
        dec_layers = [{"ln1": self._init_ln(pf), "self": self._init_mha(pf),
                       "ln2": self._init_ln(pf), "cross": self._init_mha(pf),
                       "ln3": self._init_ln(pf), "mlp": self._init_mlp(pf)}
                      for _ in range(cfg.n_layers)]
        if self.stacked:
            enc_tree: Any = {"stack": stacking.stack_boxed_trees(enc_layers)}
            dec_tree: Any = {"stack": stacking.stack_boxed_trees(dec_layers)}
        else:
            enc_tree = {"layers": enc_layers}
            dec_tree = {"layers": dec_layers}
        enc_tree["ln_post"] = self._init_ln(pf)
        dec_tree["ln_f"] = self._init_ln(pf)
        tree = {
            "embed": pf.embedding(cfg.vocab_size, cfg.d_model),
            "pos_dec": pf.embedding(self.MAX_POS, cfg.d_model,
                                    axes=(None, "embed"), scale=0.01),
            "enc": enc_tree,
            "dec": dec_tree,
        }
        return split_tree(tree)

    # ----------------------------------------------------------- sublayers
    def _mha(self, p, xq, xkv, q_pos, kv_pos, causal):
        cfg, policy = self.cfg, self.policy
        B, Sq, _ = xq.shape
        H = cfg.n_heads
        dh = cfg.resolved_head_dim
        q = fp4_linear(xq, p["wq"], p["bq"], policy=policy)
        k = fp4_linear(xkv, p["wk"], policy=policy)
        v = fp4_linear(xkv, p["wv"], p["bv"], policy=policy)
        q = q.reshape(B, Sq, H, dh)
        k = k.reshape(B, xkv.shape[1], H, dh)
        v = v.reshape(B, xkv.shape[1], H, dh)
        out = attn_mod.attention(q, k, v, q_pos, kv_pos, causal=causal,
                                 kv_chunk=cfg.attn_chunk)
        out = out.reshape(B, Sq, -1)
        return fp4_linear(out, p["wo"], p["bo"], policy=policy), (k, v)

    def _mlp(self, p, x):
        policy = self.policy
        h = jax.nn.gelu(fp4_linear(x, p["wu"], p["bu"], policy=policy),
                        approximate=True)
        return fp4_linear(h, p["wd"], p["bd"], policy=policy)

    def _ln(self, p, x):
        return layer_norm(x, p["w"], p["b"])

    def _run_layers(self, tree, body, carry, extra_xs=None):
        """Run stacked (scan) or listed (unrolled) layers. body(carry, p[,x])
        -> (carry, y)."""
        cfg = self.cfg
        if self.stacked:
            fn = jax.checkpoint(body) if cfg.remat else body
            xs = (tree["stack"], extra_xs) if extra_xs is not None else \
                tree["stack"]
            return jax.lax.scan(fn, carry, xs)
        ys = []
        for i, p in enumerate(tree["layers"]):
            fn = jax.checkpoint(body) if cfg.remat else body
            x_i = (p, jax.tree.map(lambda t: t[i], extra_xs)) \
                if extra_xs is not None else p
            carry, y = fn(carry, x_i)
            ys.append(y)
        y_out = stacking.stack_trees(ys) if ys and ys[0] is not None else None
        return carry, y_out

    # -------------------------------------------------------------- encoder
    def encode(self, params, enc_embeds):
        cfg = self.cfg
        B, S, _ = enc_embeds.shape
        x = enc_embeds.astype(self.policy.compute_dtype)
        x = x + jnp.asarray(_sinusoid(S, cfg.d_model), x.dtype)
        pos = jnp.arange(S, dtype=jnp.int32)

        def enc_layer(x, p):
            h = self._ln(p["ln1"], x)
            a, _ = self._mha(p["attn"], h, h, pos, pos, causal=False)
            x = x + a
            x = x + self._mlp(p["mlp"], self._ln(p["ln2"], x))
            return self.constrain(x), None

        x, _ = self._run_layers(params["enc"], enc_layer, x)
        return self._ln(params["enc"]["ln_post"], x)

    # -------------------------------------------------------------- decoder
    def _dec_embed(self, params, tokens, pos0=0):
        x = jnp.take(params["embed"], tokens, axis=0)
        S = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos0, S, 0)
        return (x + pe[None]).astype(self.policy.compute_dtype)

    def decode_train(self, params, tokens, memory):
        """Parallel decoder over full token sequence against enc memory."""
        cfg = self.cfg
        B, S = tokens.shape
        Sm = memory.shape[1]
        x = self._dec_embed(params, tokens)
        pos = jnp.arange(S, dtype=jnp.int32)
        mpos = jnp.arange(Sm, dtype=jnp.int32)

        def dec_layer(x, p):
            h = self._ln(p["ln1"], x)
            a, _ = self._mha(p["self"], h, h, pos, pos, causal=True)
            x = x + a
            c, _ = self._mha(p["cross"], self._ln(p["ln2"], x), memory,
                             pos, mpos, causal=False)
            x = x + c
            x = x + self._mlp(p["mlp"], self._ln(p["ln3"], x))
            return self.constrain(x), None

        x, _ = self._run_layers(params["dec"], dec_layer, x)
        return self._ln(params["dec"]["ln_f"], x)

    # ------------------------------------------------------------------ api
    def loss(self, params, batch):
        from .layers import causal_lm_loss
        memory = self.encode(params, batch["enc_embeds"])
        x = self.decode_train(params, batch["tokens"], memory)
        head_w = params["embed"].T.astype(self.policy.compute_dtype)
        lm = causal_lm_loss(x, head_w, batch["tokens"],
                            chunk=self.cfg.loss_chunk)
        return lm, {"lm_loss": lm, "aux_loss": jnp.float32(0.0)}

    def init_cache(self, batch_size: int, max_len: int, memory_len: int = 0):
        cfg = self.cfg
        dt = CACHE_DTYPES[cfg.cache_dtype]
        dh = cfg.resolved_head_dim
        memory_len = memory_len or max_len // 2
        mk = lambda L: {
            "k": jnp.zeros((batch_size, L, cfg.n_heads, dh), dt),
            "v": jnp.zeros((batch_size, L, cfg.n_heads, dh), dt),
            "kv_pos": jnp.full((batch_size, L), -1, jnp.int32),
        }
        per_layer = [{"self": mk(max_len), "cross": mk(memory_len)}
                     for _ in range(cfg.n_layers)]
        if self.stacked:
            return {"stack": stacking.stack_trees(per_layer)}
        return {"layers": per_layer}

    def _dec_layer_prefill(self, p, x, c, memory, pos, mpos):
        cfg = self.cfg
        B, S = x.shape[:2]
        dh = cfg.resolved_head_dim
        h = self._ln(p["ln1"], x)
        a, (k, v) = self._mha(p["self"], h, h, pos, pos, causal=True)
        x = x + a
        new_c = {"self": dict(c["self"]), "cross": dict(c["cross"])}
        new_c["self"]["k"] = c["self"]["k"].at[:, :S].set(
            k.astype(c["self"]["k"].dtype))
        new_c["self"]["v"] = c["self"]["v"].at[:, :S].set(
            v.astype(c["self"]["v"].dtype))
        new_c["self"]["kv_pos"] = c["self"]["kv_pos"].at[:, :S].set(pos[None])
        cc, (mk_, mv_) = self._mha(p["cross"], self._ln(p["ln2"], x), memory,
                                   pos, mpos, causal=False)
        x = x + cc
        Sm = memory.shape[1]
        new_c["cross"]["k"] = c["cross"]["k"].at[:, :Sm].set(
            mk_.astype(c["cross"]["k"].dtype))
        new_c["cross"]["v"] = c["cross"]["v"].at[:, :Sm].set(
            mv_.astype(c["cross"]["v"].dtype))
        new_c["cross"]["kv_pos"] = c["cross"]["kv_pos"].at[:, :Sm].set(
            mpos[None])
        x = x + self._mlp(p["mlp"], self._ln(p["ln3"], x))
        return self.constrain(x), new_c

    def prefill(self, params, batch, cache):
        """Encode audio memory, fill cross caches, run decoder prompt."""
        cfg = self.cfg
        memory = self.encode(params, batch["enc_embeds"])
        B, Sm = memory.shape[:2]
        mpos = jnp.arange(Sm, dtype=jnp.int32)
        tokens = batch["tokens"]
        S = tokens.shape[1]
        x = self._dec_embed(params, tokens)
        pos = jnp.arange(S, dtype=jnp.int32)

        def body(x, inp):
            p, c = inp
            return self._dec_layer_prefill(p, x, c, memory, pos, mpos)

        if self.stacked:
            fn = jax.checkpoint(body) if cfg.remat else body
            x, new_stack = jax.lax.scan(fn, x, (params["dec"]["stack"],
                                                cache["stack"]))
            new_cache = {"stack": new_stack}
        else:
            new_layers = []
            for p, c in zip(params["dec"]["layers"], cache["layers"]):
                x, nc = body(x, (p, c))
                new_layers.append(nc)
            new_cache = {"layers": new_layers}
        x = self._ln(params["dec"]["ln_f"], x)
        logits = jnp.matmul(x[:, -1], params["embed"].T.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return logits, new_cache

    def _dec_layer_step(self, p, x, c, pos, positions):
        cfg, policy = self.cfg, self.policy
        B = x.shape[0]
        dh = cfg.resolved_head_dim
        h = self._ln(p["ln1"], x)
        q = fp4_linear(h, p["self"]["wq"], p["self"]["bq"], policy=policy)
        k = fp4_linear(h, p["self"]["wk"], policy=policy)
        v = fp4_linear(h, p["self"]["wv"], p["self"]["bv"], policy=policy)
        q = q.reshape(B, 1, cfg.n_heads, dh)
        k = k.reshape(B, 1, cfg.n_heads, dh)
        v = v.reshape(B, 1, cfg.n_heads, dh)
        cs = c["self"]
        ck = jax.lax.dynamic_update_slice(cs["k"], k.astype(cs["k"].dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cs["v"], v.astype(cs["v"].dtype),
                                          (0, pos, 0, 0))
        cp = jax.lax.dynamic_update_slice(cs["kv_pos"], positions, (0, pos))
        out = attn_mod.dense_attention(q, ck.astype(q.dtype),
                                       cv.astype(q.dtype), positions, cp,
                                       causal=True)
        x = x + fp4_linear(out.reshape(B, 1, -1), p["self"]["wo"],
                           p["self"]["bo"], policy=policy)
        h = self._ln(p["ln2"], x)
        qc = fp4_linear(h, p["cross"]["wq"], p["cross"]["bq"],
                        policy=policy).reshape(B, 1, cfg.n_heads, dh)
        mc = c["cross"]
        out = attn_mod.dense_attention(
            qc, mc["k"].astype(qc.dtype), mc["v"].astype(qc.dtype),
            positions, mc["kv_pos"], causal=False)
        x = x + fp4_linear(out.reshape(B, 1, -1), p["cross"]["wo"],
                           p["cross"]["bo"], policy=policy)
        x = x + self._mlp(p["mlp"], self._ln(p["ln3"], x))
        new_c = {"self": {"k": ck, "v": cv, "kv_pos": cp}, "cross": mc}
        return x, new_c

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,1); pos: scalar decoder position."""
        cfg, policy = self.cfg, self.policy
        B = tokens.shape[0]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, 0)
        x = (jnp.take(params["embed"], tokens, axis=0) + pe[None]).astype(
            policy.compute_dtype)
        positions = jnp.full((B, 1), pos, jnp.int32)

        def body(x, inp):
            p, c = inp
            return self._dec_layer_step(p, x, c, pos, positions)

        if self.stacked:
            x, new_stack = jax.lax.scan(body, x, (params["dec"]["stack"],
                                                  cache["stack"]))
            new_cache = {"stack": new_stack}
        else:
            new_layers = []
            for p, c in zip(params["dec"]["layers"], cache["layers"]):
                x, nc = body(x, (p, c))
                new_layers.append(nc)
            new_cache = {"layers": new_layers}
        x = self._ln(params["dec"]["ln_f"], x)
        logits = jnp.matmul(x[:, 0], params["embed"].T.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return logits, new_cache
