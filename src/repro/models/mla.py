"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Q path:  x -> W_dq (q_lora) -> norm -> W_uq -> heads x (nope + rope)
KV path: x -> W_dkv -> [c_kv (kv_lora) | k_rope (shared)] ; c_kv -> norm
         c_kv -> W_ukv -> heads x (nope + v)

The decode cache stores only (c_kv, k_rope): the compressed-latent memory
saving that makes MLA attractive. K/V are re-expanded from the latent at
decode time (naive MLA; the absorbed-matmul variant is a serve-side
optimization, see EXPERIMENTS.md §Perf).

All projections are GeMMs -> fp4_linear applies (paper's technique maps
cleanly onto MLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import fp4_linear
from repro.core.policy import QuantPolicy

from . import attention as attn_mod
from .blocks import CACHE_DTYPES
from .layers import apply_rope, rms_norm
from .param import ParamFactory


def _n_heads(cfg) -> int:
    """Head count used by the MLA compute graph. cfg.mla_pad_heads > n_heads
    pads with extra (zero-contribution after W_o) heads so the flat head
    dims divide the 16-way 'model' axis -- without it, GSPMD cannot shard
    the (H, head_dim) reshape when H % 16 != 0 and replicates the whole
    attention (the minicpm3 §Perf hillclimb move)."""
    return max(cfg.n_heads, getattr(cfg, "mla_pad_heads", 0) or 0)


def init_mla(pf: ParamFactory, cfg):
    H = _n_heads(cfg)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "w_dq": pf.dense(cfg.d_model, cfg.q_lora_rank, ("embed", None)),
        "q_norm": pf.ones((cfg.q_lora_rank,), (None,)),
        "w_uq": pf.dense(cfg.q_lora_rank, H * qk_dim, (None, "heads")),
        "w_dkv": pf.dense(cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim,
                          ("embed", None)),
        "kv_norm": pf.ones((cfg.kv_lora_rank,), (None,)),
        "w_ukv": pf.dense(cfg.kv_lora_rank,
                          H * (cfg.qk_nope_dim + cfg.v_head_dim),
                          (None, "heads")),
        "wo": pf.dense(H * cfg.v_head_dim, cfg.d_model, ("heads", "embed")),
    }


def _q_proj(p, x, positions, cfg, policy):
    B, S, _ = x.shape
    H = _n_heads(cfg)
    cq = rms_norm(fp4_linear(x, p["w_dq"], policy=policy), p["q_norm"])
    q = fp4_linear(cq, p["w_uq"], policy=policy)
    q = q.reshape(B, S, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _kv_latent(p, x, positions, cfg, policy):
    """Returns (c_kv normalized, k_rope roped): exactly what decode caches."""
    ckv_full = fp4_linear(x, p["w_dkv"], policy=policy)
    c_kv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def _expand_kv(p, c_kv, k_rope, cfg, policy):
    B, S, _ = c_kv.shape
    H = _n_heads(cfg)
    kv = fp4_linear(c_kv, p["w_ukv"], policy=policy)
    kv = kv.reshape(B, S, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, cfg.qk_rope_dim)).astype(k_nope.dtype)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_train(p, x, positions, cfg, policy: QuantPolicy):
    B, S, _ = x.shape
    q = _q_proj(p, x, positions, cfg, policy)
    c_kv, k_rope = _kv_latent(p, x, positions, cfg, policy)
    k, v = _expand_kv(p, c_kv, k_rope, cfg, policy)
    # v head dim differs from qk dim; pad v for the shared attention helper,
    # slice after (keeps one attention implementation).
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - cfg.v_head_dim)))
    out = attn_mod.attention(q, k, v_pad, positions, positions, causal=True,
                             kv_chunk=cfg.attn_chunk)
    out = out[..., :cfg.v_head_dim].reshape(B, S, -1)
    return fp4_linear(out, p["wo"], policy=policy)


def init_mla_cache(cfg, batch: int, max_len: int):
    dt = CACHE_DTYPES[cfg.cache_dtype]
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dt),
        "kv_pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_prefill(p, x, positions, cache, cfg, policy: QuantPolicy):
    """Parallel prompt processing; caches the compressed latents."""
    B, S, _ = x.shape
    q = _q_proj(p, x, positions, cfg, policy)
    c_kv, k_rope = _kv_latent(p, x, positions, cfg, policy)
    k, v = _expand_kv(p, c_kv, k_rope, cfg, policy)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - cfg.v_head_dim)))
    out = attn_mod.attention(q, k, v_pad, positions, positions, causal=True,
                             kv_chunk=cfg.attn_chunk)
    out = out[..., :cfg.v_head_dim].reshape(B, S, -1)
    y = fp4_linear(out, p["wo"], policy=policy)
    ck = cache["c_kv"].at[:, :S].set(c_kv.astype(cache["c_kv"].dtype))
    cr = cache["k_rope"].at[:, :S].set(k_rope.astype(cache["k_rope"].dtype))
    pos2d = positions[None] if positions.ndim == 1 else positions
    cpos = cache["kv_pos"].at[:, :S].set(pos2d)
    return y, {"c_kv": ck, "k_rope": cr, "kv_pos": cpos}


def mla_decode(p, x, cache, pos, cfg, policy: QuantPolicy):
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = _q_proj(p, x, positions, cfg, policy)
    c_kv, k_rope = _kv_latent(p, x, positions, cfg, policy)
    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, pos, 0))
    cpos = jax.lax.dynamic_update_slice(cache["kv_pos"], positions, (0, pos))
    k, v = _expand_kv(p, ck.astype(x.dtype), cr.astype(x.dtype), cfg, policy)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - cfg.v_head_dim)))
    out = attn_mod.dense_attention(q, k, v_pad, positions, cpos, causal=True)
    out = out[..., :cfg.v_head_dim].reshape(B, 1, -1)
    y = fp4_linear(out, p["wo"], policy=policy)
    return y, {"c_kv": ck, "k_rope": cr, "kv_pos": cpos}
