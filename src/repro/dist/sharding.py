"""Logical-axis -> mesh sharding rules (GSPMD side of the recipe).

Models annotate every parameter with *logical* axis names
(models/param.py) and never mention mesh axes. This module owns the
mapping onto a ('pod', 'data', 'model') mesh:

  * tensor-parallel names -> 'model': the highest-priority divisible
    name wins (priority: mlp > heads > kv_heads > vocab > expert >
    embed > embed2, i.e. wide contraction-free dims first, Megatron
    column/row style); every other dim replicates. One mesh axis is
    never assigned to two tensor dims.
  * 'batch' -> all data-parallel axes present ('pod' outer, 'data'
    inner) when the dim is divisible by the total DP world size,
    replicated otherwise.
  * 'seq'   -> 'model' (sequence parallelism between layers) when
    divisible and 'model' is still unused; applied by
    `make_act_constraint`.

Rules degrade to replication instead of erroring: the same model code
must lower on a 512-chip production mesh and an 8-fake-device test mesh
(smoke dims rarely divide evenly). KV caches (`cache_shardings`) are
positional -- batch dim -> DP axes, sequence dim -> 'model' (DESIGN.md
§4) -- because cache pytrees carry no logical annotations.
"""
from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.dist import compat

# Wide, contraction-free dims first; 'embed' last so ("embed", "mlp")
# shards the MLP dim (column parallel) and the matching ("mlp", "embed")
# down-projection shards its *input* dim (row parallel) -- activations
# then need exactly one collective per MLP pair, Megatron-style.
_TP_PRIORITY = ("mlp", "heads", "kv_heads", "vocab", "expert", "embed",
                "embed2")


def _sizes(mesh) -> dict:
    return dict(mesh.shape)


def data_axes(mesh) -> tuple[str, ...]:
    """All batch-parallel axes present in the mesh ('pod' is outer DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    sizes = _sizes(mesh)
    n = 1
    for a in data_axes(mesh):
        n *= sizes[a]
    return n


def logical_to_spec(logical_axes, shape, mesh) -> P:
    """Map a tuple of logical axis names onto a PartitionSpec for `shape`.

    `mesh` only needs `.axis_names` and a dict-like `.shape` (tests pass
    a plain stand-in object).
    """
    sizes = _sizes(mesh)
    names = tuple(mesh.axis_names)
    la = tuple(logical_axes) + (None,) * (len(shape) - len(logical_axes))
    entries: list = [None] * len(shape)
    used: set[str] = set()

    # batch -> (pod, data), first batch dim only, all-or-nothing
    dps = data_axes(mesh)
    dp = dp_size(mesh)
    for i, (name, dim) in enumerate(zip(la, shape)):
        if name == "batch" and dps and dp > 1 and dim % dp == 0:
            entries[i] = dps if len(dps) > 1 else dps[0]
            used.update(dps)
            break

    # tensor parallelism -> 'model', single highest-priority divisible dim
    model = sizes.get("model", 0)
    if "model" in names and model > 1 and "model" not in used:
        best_i, best_rank = -1, len(_TP_PRIORITY)
        for i, (name, dim) in enumerate(zip(la, shape)):
            if entries[i] is not None or name not in _TP_PRIORITY:
                continue
            if dim % model != 0:
                continue
            rank = _TP_PRIORITY.index(name)
            if rank < best_rank:
                best_i, best_rank = i, rank
        if best_i >= 0:
            entries[best_i] = "model"
            used.add("model")

    # sequence parallelism: 'seq' -> 'model' if still free
    if "model" in names and model > 1 and "model" not in used:
        for i, (name, dim) in enumerate(zip(la, shape)):
            if name == "seq" and entries[i] is None and dim % model == 0:
                entries[i] = "model"
                used.add("model")
                break

    return P(*entries)


def param_specs(axes, params, mesh):
    """PartitionSpec tree for a param pytree given its logical-axes tree.

    `axes` mirrors `params` container-for-container with tuple leaves
    (the shape model.init returns), so it is flattened *up to* the param
    tree's leaf positions rather than fully (tuples are pytrees too).
    Pure logic -- `mesh` can be any object with axis_names + dict shape.
    """
    flat_p, treedef = jax.tree.flatten(params)
    flat_a = treedef.flatten_up_to(axes)
    return treedef.unflatten([logical_to_spec(a, p.shape, mesh)
                              for a, p in zip(flat_a, flat_p)])


def param_shardings(axes, params, mesh):
    """NamedShardings for a param pytree (requires a real device mesh)."""
    specs = param_specs(axes, params, mesh)
    flat_s, treedef = jax.tree.flatten(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    return treedef.unflatten([NamedSharding(mesh, s) for s in flat_s])


def _positional_spec(shape, offset: int, mesh) -> P:
    """batch dim at `offset` -> DP axes, next (sequence) dim -> 'model'."""
    sizes = _sizes(mesh)
    entries: list = [None] * len(shape)
    dps = data_axes(mesh)
    dp = dp_size(mesh)
    if len(shape) > offset and dps and dp > 1 and shape[offset] % dp == 0:
        entries[offset] = dps if len(dps) > 1 else dps[0]
    model = sizes.get("model", 0)
    if ("model" in mesh.axis_names and model > 1
            and len(shape) > offset + 1 and shape[offset + 1] % model == 0):
        entries[offset + 1] = "model"
    return P(*entries)


def _pages_spec(shape, offset: int, mesh) -> P:
    """Paged KV pool (n_pages, page_size, kv_heads, head_dim) at `offset`:
    the kv-heads dim -> 'model' (tensor-parallel KV, matching the wk/wv
    column sharding); page and page-offset dims replicate -- every model
    shard must reach every page, only the head slice is local."""
    sizes = _sizes(mesh)
    entries: list = [None] * len(shape)
    model = sizes.get("model", 0)
    head_dim = offset + 2
    if ("model" in mesh.axis_names and model > 1
            and len(shape) > head_dim and shape[head_dim] % model == 0):
        entries[head_dim] = "model"
    return P(*entries)


def cache_specs(cache, mesh):
    """PartitionSpec tree for a KV-cache pytree (serve/decode path).

    Dense cache leaves are positional: (batch, seq, ...) normally, with
    one extra leading layer-group dim under the "stack" key when the
    model runs scan-over-layers (models/stacking.py). batch -> DP axes,
    cache sequence dim -> 'model' (2D cache sharding, DESIGN.md §4); the
    layer-group dim always replicates (it is the scan axis). Paged pool
    leaves (key `k_pages`/`v_pages`, serve/paged_cache.py) shard their
    kv-heads dim over 'model' instead (`_pages_spec`).
    """
    def one(path, x):
        stacked = bool(path) and isinstance(path[0], DictKey) \
            and path[0].key == "stack"
        paged = bool(path) and isinstance(path[-1], DictKey) \
            and path[-1].key.endswith("_pages")
        if paged:
            return _pages_spec(x.shape, 1 if stacked else 0, mesh)
        return _positional_spec(x.shape, 1 if stacked else 0, mesh)
    return tree_map_with_path(one, cache)


def cache_shardings(cache, mesh):
    """NamedShardings for a KV-cache pytree (requires a real device mesh)."""
    specs = cache_specs(cache, mesh)
    flat_s, treedef = jax.tree.flatten(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    return treedef.unflatten([NamedSharding(mesh, s) for s in flat_s])


def make_act_constraint(mesh, *, seq_parallel: bool = True
                        ) -> Callable | None:
    """Activation sharding constraint injected between model layers.

    Returns f(x) -> x with a with_sharding_constraint attached: batch
    dim over the DP axes and -- when `seq_parallel` -- the sequence dim
    over 'model', so layer boundaries stay sequence-sharded and GSPMD
    places the all-gather/reduce-scatter pair around attention/MLP
    instead of keeping full activations per chip. Non-divisible dims
    replicate (decode steps have seq=1). Arrays below rank 2 (scalars,
    per-token aux losses) pass through untouched.
    """
    if getattr(mesh, "size", 2) <= 1:
        return lambda x: x

    def constrain(x):
        if getattr(x, "ndim", 0) < 2:
            return x
        # inside a shard_map region (hier train step: manual 'pod') a
        # constraint naming auto axes trips the XLA SPMD partitioner's
        # Manual-subgroup CHECK (DESIGN.md §8b) -- let GSPMD place the
        # inner region freely instead
        if compat.manual_axis_names():
            return x
        la = ["batch"] + [None] * (x.ndim - 1)
        if seq_parallel and x.ndim >= 3:
            la[1] = "seq"
        spec = logical_to_spec(tuple(la), x.shape, mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return constrain
