"""jax version bridge for the distribution layer.

The repo codes against the modern distribution API (``jax.set_mesh``,
``jax.shard_map``, typed mesh axes). Older jaxlibs (0.4.x) expose the
same machinery under different names and signatures; this module is the
single import site that papers over the difference so callers never
version-check themselves.
"""
from __future__ import annotations

import jax


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def set_mesh(mesh):
    """Context manager activating `mesh` for name-based sharding.

    jax >= 0.5: jax.set_mesh / jax.sharding.use_mesh. jax 0.4.x: the
    Mesh object is itself the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def manual_axis_names() -> set:
    """Mesh axes bound *manually* at the current trace point (i.e. we are
    inside a shard_map region over them). Sharding constraints must not
    reference these -- the partitioner rejects specs naming manual axes.
    """
    # new jax: the active abstract mesh records Manual axis types
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and getattr(m, "manual_axes", None):
            return set(m.manual_axes)
    except AttributeError:
        pass
    # jax 0.4.x: shard_map binds manual axes in the named-axis env
    try:
        from jax._src import core as _core
        return set(_core.get_axis_env().axis_sizes)
    except Exception:
        return set()


def tree_flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` (new) / tree_util spelling (0.4.x)."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def cost_analysis(compiled) -> dict:
    """Normalized `compiled.cost_analysis()`: jax 0.4.x returns a
    one-element list of dicts, newer jax the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` on new jax; experimental shard_map on 0.4.x.

    `axis_names` selects the *manual* axes (None = all); on old jax the
    complement is passed as `auto` and `check_vma` maps to `check_rep`
    (must be False when mixing Manual with Auto axes -- DESIGN.md §9).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    manual = frozenset(axis_names) if axis_names is not None else \
        frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma) and not auto, auto=auto)
