"""Low-bit gradient synchronization across the inter-pod (DCI) axis.

FP8-LM-style compression (arXiv:2310.18313 §3): gradients crossing the
slow inter-pod hop are rounded onto a per-tensor-scaled e4m3 grid
before the all-reduce. The scale is shared across the axis -- every
pod quantizes on the same grid -- by taking a pmax of the per-pod
absmax first:

    amax  = pmax_axis( max|g| )
    s     = E4M3_MAX / amax          (1.0 for all-zero tensors)
    q     = round_e4m3(g * s)        # the 1-byte payload
    mean  = psum_axis(q) / (s * N)

Accumulation runs in f32: e4m3 addition would overflow/swamp, and
FP8-LM likewise carries the reduction in higher precision
("pre-scaling", their §3.1). Note the simulation caveat: an
fp8-transport collective (1 byte/elem between ring hops, wider
accumulator inside) is a hardware/NCCL capability XLA's CPU lowering
cannot express, so here the psum *operand* is the dequantized payload
at f32 width -- HLO collective-byte metering (launch/dryrun.py) will
NOT show the 4x-vs-f32 wire saving on the hier arm; what this module
reproduces is the compression *numerics* (grid, shared scale, mean
semantics). `fp8_compress`/`fp8_decompress` are exposed separately so
the round trip is property-testable without devices.

All functions take a grads pytree and the manual mesh axis name; they
must run inside shard_map with that axis manual (train_step's hier
variant). Divergence vs the bf16 path is bounded by the e4m3 half-ulp
(<= 2^-4 relative for normals): the fake-device harness pins <5e-3 on
post-step params, tests/test_dist_grad_comm.py pins the per-element
round-trip bound directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats

E4M3_MAX = float(formats.FP8_E4M3_MAX)


def fp8_compress(x: jnp.ndarray, amax=None):
    """Per-tensor scaled e4m3 wire format. Returns (q_fp8, f32 scale).

    `amax` overrides the local absmax (pass the pmax across the reduce
    axis so all participants share one grid). Tensors with absmax below
    ~1e-30 get scale 1.0: they carry no representable signal and an f32
    scale would overflow (cf. core/quantize.absmax_scale).
    """
    xf = x.astype(jnp.float32)
    if amax is None:
        amax = jnp.max(jnp.abs(xf))
    amax = amax.astype(jnp.float32)
    scale = E4M3_MAX / jnp.where(amax > 1e-30, amax, E4M3_MAX)
    return (xf * scale).astype(jnp.float8_e4m3fn), scale


def fp8_decompress(q: jnp.ndarray, scale, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) / scale).astype(dtype)


def fp8_allreduce_mean(grads, axis_name: str):
    """Mean-reduce a grads pytree over `axis_name` in e4m3 wire format."""
    size = jax.lax.psum(1, axis_name)

    def one(g):
        gf = g.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        q, scale = fp8_compress(gf, amax=amax)
        total = jax.lax.psum(q.astype(jnp.float32), axis_name)
        return (total / (scale * size)).astype(g.dtype)

    return jax.tree.map(one, grads)


def bf16_allreduce_mean(grads, axis_name: str):
    """Baseline arm: bf16 wire format, f32 mean."""
    size = jax.lax.psum(1, axis_name)

    def one(g):
        total = jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        return (total.astype(jnp.float32) / size).astype(g.dtype)

    return jax.tree.map(one, grads)
