"""Distribution substrate: sharding rules + low-bit gradient comms.

The paper's FP4 recipe rides on a conventional mixed-precision
*distributed* scheme -- vector-wise quantized GEMMs inside the model,
sharded data/tensor parallelism and low-bit gradient sync outside
(FP8-LM, arXiv:2310.18313). This package owns everything mesh-shaped:

  sharding.py  -- logical-axis -> PartitionSpec rules, param/cache
                  shardings, activation constraints (GSPMD side).
  grad_comm.py -- fp8/bf16 gradient all-reduce across the inter-pod
                  axis (shard_map side).
  compat.py    -- jax version bridge (set_mesh / shard_map / typed
                  mesh axes moved between jax 0.4.x and 0.5+).

Models never import this package; they annotate parameters with logical
axis names (models/param.py) and accept an opaque activation-constraint
callable. Trainers/serving resolve those names here.
"""
from . import compat, grad_comm, sharding

__all__ = ["compat", "grad_comm", "sharding"]
