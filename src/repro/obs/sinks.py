"""Host-side sinks for quantization-health metrics.

`JsonlWriter`   -- append-mode JSON-lines step-metrics log (one record per
                   training/decode step; schema in DESIGN.md §11).
`RollingWindow` -- bounded in-memory window with percentile summaries, the
                   thing a dashboard (or the collapse sentinel's operator)
                   reads without scanning the JSONL.
"""
from __future__ import annotations

import collections
import json
import os

import numpy as np


class JsonlWriter:
    """Append-only JSONL sink. Opens lazily, flushes every record (a
    collapse postmortem must see the last pre-divergence step)."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def _ensure_open(self):
        if self._f is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "a")
        return self._f

    def write(self, record: dict) -> None:
        f = self._ensure_open()
        f.write(json.dumps(record, sort_keys=True) + "\n")
        f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path: str) -> list[dict]:
    """Load a health log back (tests, notebooks, postmortems)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class RollingWindow:
    """Last-N step records with percentile summaries per metric key."""

    def __init__(self, size: int = 128):
        self._buf: collections.deque[dict] = collections.deque(maxlen=size)

    def push(self, record: dict) -> None:
        self._buf.append(record)

    def __len__(self) -> int:
        return len(self._buf)

    def summary(self, keys: list[str] | None = None,
                percentiles=(50.0, 95.0)) -> dict[str, dict]:
        """{key: {p50, p95, min, max, last}} over the window. Non-numeric
        record fields are skipped."""
        if not self._buf:
            return {}
        if keys is None:
            keys = sorted({k for rec in self._buf for k in rec
                           if isinstance(rec[k], (int, float))})
        out: dict[str, dict] = {}
        for key in keys:
            vals = [rec[key] for rec in self._buf
                    if isinstance(rec.get(key), (int, float))]
            if not vals:
                continue
            arr = np.asarray(vals, np.float64)
            stats = {f"p{int(p)}": float(np.percentile(arr, p))
                     for p in percentiles}
            stats.update(min=float(arr.min()), max=float(arr.max()),
                         last=float(arr[-1]))
            out[key] = stats
        return out
