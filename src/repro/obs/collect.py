"""Jit-compatible quantization-health metrics collection.

The collector is a *trace-time* object: `obs.collect()` installs a
thread-local `MetricsCollector`, the FP4 compute path (`core/linear.py`,
`core/fp4_gemm.py`, `kernels/ops.py`) records per-site scalar statistics
into it while the surrounding function is being traced, and the owner of
the trace (`CausalLM.loss`, `serve.engine`) harvests the records *inside
the same trace* and returns them as part of its metrics pytree. No host
callbacks: the recorded values are ordinary traced f32 scalars, so the
whole scheme survives `jit` (and rides through `value_and_grad` as aux
outputs -- every record is `stop_gradient`ed).

Trace-safety rule: a value recorded under an *inner* trace (lax.scan body,
jax.checkpoint/remat region, vmap) must not be harvested outside it --
that is an escaped tracer. Call sites that introduce inner traces suspend
collection around them (`obs.suspended()` in `models/transformer.py` for
the stacked-scan path and remat-wrapped layers, `models/blocks.py` around
the MoE expert vmap). Net effect: full per-layer telemetry requires the
unrolled, remat-off execution mode (the observability configuration used
by smoke trains and CPU tests); production dry-runs keep obs off via
`QuantPolicy.obs_metrics=False` (the default). See DESIGN.md §11.

Metric vocabulary (leaf key -> meaning, paper grounding in DESIGN.md §11):
    clamp_frac      fraction of activation elements moved by OCC clamping
    residual_mass   |Delta|_1 / |A|_1 -- outlier mass routed to the
                    compensation path (paper §3.2)
    scale_min/max   per-tensor extrema of the absmax quantization scales
    underflow_frac  fraction of quantization groups whose absmax is below
                    the f32-safe floor (scale forced to 1; signal lost)
    mse, snr_db     quantize->dequantize error vs the input tensor
    dge_mismatch    ||Q(x) - x||_2 / ||x||_2 on the scaled weight -- the
                    gap between the DGE hard forward and the identity its
                    backward linearizes around (paper §3.1)
    dge_fprime_mean mean DGE derivative f'(x) (1.0 == STE regime)
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp

_STATE = threading.local()

# absmax floor mirrored from core.quantize.absmax_scale: groups below it get
# scale 1.0 (their content is not representable at 4 bits).
UNDERFLOW_ABSMAX = 1e-30


class MetricsCollector:
    """Accumulates named scalar records during one trace."""

    def __init__(self):
        self._records: dict[str, jnp.ndarray] = {}
        self._scopes: list[str] = []
        self._auto_site = 0
        self._suspended = 0

    # ---------------------------------------------------------------- record
    def next_site_name(self, name: str | None = None) -> str:
        if name is not None:
            return name
        name = f"site{self._auto_site}"
        self._auto_site += 1
        return name

    def record(self, key: str, value) -> None:
        if self._suspended:
            return
        full = "/".join(self._scopes + [key])
        self._records[full] = jax.lax.stop_gradient(
            jnp.asarray(value, jnp.float32))

    # --------------------------------------------------------------- harvest
    def harvest(self) -> dict[str, jnp.ndarray]:
        """Flat {key: f32 scalar} dict incl. cross-site aggregates. Must be
        called at the same trace level the records were made at."""
        out = dict(self._records)
        out.update(aggregate(self._records))
        return out


# Aggregation op per metric leaf: the sentinel watches the *worst* site.
_AGG_OPS = {
    "clamp_frac": "max",
    "residual_mass": "max",
    "underflow_frac": "max",
    "snr_db": "min",
    "mse": "max",
    "dge_mismatch": "max",
    "scale_min": "min",
    "scale_max": "max",
}


def aggregate(records: dict[str, jnp.ndarray]) -> dict[str, jnp.ndarray]:
    """Worst-case-across-sites summaries ('agg/min_snr_db', ...)."""
    groups: dict[str, list[jnp.ndarray]] = {}
    for key, value in records.items():
        leaf = key.rsplit("/", 1)[-1]
        groups.setdefault(leaf, []).append(value)
    out: dict[str, jnp.ndarray] = {}
    for leaf, vals in groups.items():
        op = _AGG_OPS.get(leaf)
        if op is None:
            continue
        out[f"agg/{op}_{leaf}"] = getattr(jnp, op)(jnp.stack(vals))
    if groups:
        n = max(len(v) for v in groups.values())
        out["agg/n_sites"] = jnp.float32(n)
    return out


# ---------------------------------------------------------------------------
# Thread-local plumbing
# ---------------------------------------------------------------------------

def active() -> MetricsCollector | None:
    """The installed collector, or None if absent/suspended."""
    col = getattr(_STATE, "collector", None)
    if col is None or col._suspended:
        return None
    return col


@contextmanager
def collect(enabled: bool = True):
    """Install a fresh collector for the duration of the block. Yields the
    collector (or None when disabled) -- harvest it before leaving the
    trace that produced the records."""
    if not enabled:
        yield None
        return
    prev = getattr(_STATE, "collector", None)
    col = MetricsCollector()
    _STATE.collector = col
    try:
        yield col
    finally:
        _STATE.collector = prev


@contextmanager
def scope(name: str):
    """Prefix records inside the block with `name/` (layers, sublayers)."""
    col = getattr(_STATE, "collector", None)
    if col is None:
        yield
        return
    col._scopes.append(name)
    try:
        yield
    finally:
        col._scopes.pop()


@contextmanager
def site(name: str | None = None):
    """Scope for one instrumented GeMM site; auto-numbered when unnamed.
    Yields True when records will actually be kept."""
    col = active()
    if col is None:
        yield False
        return
    with scope(col.next_site_name(name)):
        yield True


@contextmanager
def suspended():
    """No-op recording inside the block. Used around inner traces (scan,
    remat, vmap) whose tracers must not leak into the harvest."""
    col = getattr(_STATE, "collector", None)
    if col is None:
        yield
        return
    col._suspended += 1
    try:
        yield
    finally:
        col._suspended -= 1


def suppress(fn):
    """Wrap `fn` so it runs with recording suspended (for remat/scan
    bodies that are traced at an inner level)."""
    def wrapped(*args, **kwargs):
        with suspended():
            return fn(*args, **kwargs)
    return wrapped


# ---------------------------------------------------------------------------
# Recording helpers: each is a no-op (zero traced ops) when no collector is
# active, so the instrumented hot path costs nothing with obs off.
# ---------------------------------------------------------------------------

def record(key: str, value) -> None:
    col = active()
    if col is not None:
        col.record(key, value)


def quant_error_stats(x: jnp.ndarray, x_hat: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """MSE and SNR (dB) of a reconstruction `x_hat` against `x`."""
    a = x.astype(jnp.float32).reshape(-1)
    b = x_hat.astype(jnp.float32).reshape(-1)
    mse = jnp.mean((a - b) ** 2)
    snr = 10.0 * jnp.log10(jnp.mean(a ** 2) / jnp.maximum(mse, 1e-20))
    return {"mse": mse, "snr_db": snr}


def record_clamp(x: jnp.ndarray, residual: jnp.ndarray) -> None:
    """OCC health: how much of the tensor the clamp moved, and how much
    mass the compensation path must carry."""
    col = active()
    if col is None:
        return
    r = residual.astype(jnp.float32)
    col.record("clamp_frac", jnp.mean((r != 0).astype(jnp.float32)))
    total = jnp.sum(jnp.abs(x.astype(jnp.float32))) + 1e-12
    col.record("residual_mass", jnp.sum(jnp.abs(r)) / total)


def record_scale(kind: str, x: jnp.ndarray, scale: jnp.ndarray,
                 axis) -> None:
    """Scale health for one quantized operand (`kind` in {'act','weight'}):
    extrema of the absmax scales plus the underflow fraction."""
    col = active()
    if col is None:
        return
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                   keepdims=axis is not None)
    with scope(kind):
        col.record("scale_min", jnp.min(scale))
        col.record("scale_max", jnp.max(scale))
        col.record("underflow_frac",
                   jnp.mean((amax <= UNDERFLOW_ABSMAX).astype(jnp.float32)))


def record_quant_error(kind: str, x: jnp.ndarray, x_q: jnp.ndarray,
                       scale: jnp.ndarray) -> None:
    """Quantize->dequantize fidelity of `x_q` (on-grid, scaled) vs `x`."""
    col = active()
    if col is None:
        return
    deq = x_q.astype(jnp.float32) / scale
    stats = quant_error_stats(x, deq)
    with scope(kind):
        for k, v in stats.items():
            col.record(k, v)


def record_dge(w_scaled: jnp.ndarray, w_q: jnp.ndarray,
               fprime: jnp.ndarray | None = None) -> None:
    """DGE forward/backward mismatch: relative L2 gap between the hard
    forward Q(x) and the scaled input the backward linearizes around."""
    col = active()
    if col is None:
        return
    a = w_scaled.astype(jnp.float32).reshape(-1)
    b = w_q.astype(jnp.float32).reshape(-1)
    denom = jnp.maximum(jnp.linalg.norm(a), 1e-12)
    with scope("weight"):
        col.record("dge_mismatch", jnp.linalg.norm(b - a) / denom)
        if fprime is not None:
            col.record("dge_fprime_mean",
                       jnp.mean(fprime.astype(jnp.float32)))
