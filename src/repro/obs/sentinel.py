"""Activation-collapse sentinel.

Watches the aggregated quant-health metrics emitted by `repro.obs.collect`
and trips when the FP4 path shows a *sustained* collapse signature -- the
failure modes the paper's stability mechanisms exist to prevent:

  * quant SNR falling through the floor (absmax scale blown out by
    outliers: the tensor body quantizes to zero -- paper §3.2 / Fig. 4),
  * clamp fraction far above the 2*(1-alpha) the OCC quantile design
    admits (threshold estimation broke down),
  * residual mass dominating the tensor (the "compensated" path is now
    carrying the signal; the FP4 GeMM computes noise),
  * scale-group underflow (tokens/channels whose absmax is below the f32
    floor -- they lost all signal).

"FP4 All the Way" (Chmiel et al., 2025) observes these trends move steps
*before* the loss does, which is the window in which skipping the update,
checkpointing, and falling back to bf16 is still cheap. `patience`
consecutive unhealthy steps are required (one outlier batch is not a
collapse); `warmup_steps` observations are ignored while scales settle.
"""
from __future__ import annotations

import dataclasses
import math

from repro.chaos.hooks import chaos_point


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    min_snr_db: float = 6.0          # healthy E2M1 token-wise SNR is >~10 dB
    max_clamp_frac: float = 0.25     # >> 2*(1-alpha) at alpha=0.99
    max_underflow_frac: float = 0.01
    max_residual_mass: float = 0.5   # compensation path carries the signal
    max_dge_mismatch: float | None = None  # off by default (format-dependent)
    patience: int = 2                # consecutive unhealthy steps to trip
    warmup_steps: int = 2            # ignore the first N observations


@dataclasses.dataclass
class SentinelDecision:
    tripped: bool
    step: int
    reasons: list[str]
    streak: int


class CollapseSentinel:
    """Feed one aggregated obs record per step; returns a decision."""

    def __init__(self, cfg: SentinelConfig | None = None):
        self.cfg = cfg or SentinelConfig()
        self.n_obs = 0
        self.streak = 0
        self.trips: list[SentinelDecision] = []

    def _breaches(self, obs: dict) -> list[str]:
        cfg = self.cfg
        checks = [
            ("agg/min_snr_db", lambda v: v < cfg.min_snr_db,
             f"snr_db<{cfg.min_snr_db}"),
            ("agg/max_clamp_frac", lambda v: v > cfg.max_clamp_frac,
             f"clamp_frac>{cfg.max_clamp_frac}"),
            ("agg/max_underflow_frac", lambda v: v > cfg.max_underflow_frac,
             f"underflow_frac>{cfg.max_underflow_frac}"),
            ("agg/max_residual_mass", lambda v: v > cfg.max_residual_mass,
             f"residual_mass>{cfg.max_residual_mass}"),
        ]
        if cfg.max_dge_mismatch is not None:
            checks.append(("agg/max_dge_mismatch",
                           lambda v: v > cfg.max_dge_mismatch,
                           f"dge_mismatch>{cfg.max_dge_mismatch}"))
        reasons = []
        for key, bad, label in checks:
            v = obs.get(key)
            if v is None:
                continue
            v = float(v)
            # A non-finite health metric is itself a collapse signal.
            if not math.isfinite(v) or bad(v):
                reasons.append(f"{label} (got {v:.4g})")
        return reasons

    def observe(self, step: int, obs: dict) -> SentinelDecision:
        # chaos seam: scenario injectors overwrite the health record here
        # to exercise trip -> checkpoint -> bf16-fallback (DESIGN.md §15)
        obs = chaos_point("sentinel.obs", obs, step=step)
        self.n_obs += 1
        if self.n_obs <= self.cfg.warmup_steps:
            return SentinelDecision(False, step, [], 0)
        reasons = self._breaches(obs)
        if reasons:
            self.streak += 1
        else:
            self.streak = 0
        tripped = self.streak >= self.cfg.patience
        decision = SentinelDecision(tripped, step, reasons, self.streak)
        if tripped:
            self.trips.append(decision)
            self.streak = 0   # re-arm after the trip is acted upon
        return decision
