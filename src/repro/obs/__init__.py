"""repro.obs -- quantization-health observability (DESIGN.md §11).

Three pieces:
  * `collect`  -- jit-compatible trace-time metrics collection threaded
                  through the FP4 compute path (clamp fraction, residual
                  mass, scale extrema/underflow, quant SNR/MSE, DGE
                  forward/backward mismatch);
  * `sinks`    -- JSONL step-metrics writer + rolling percentile window;
  * `sentinel` -- activation-collapse sentinel that trips on sustained
                  unhealthy trends and drives the trainer's skip/
                  checkpoint/bf16-fallback machinery.
"""
from .collect import (UNDERFLOW_ABSMAX, MetricsCollector, active, aggregate,
                      collect, quant_error_stats, record, record_clamp,
                      record_dge, record_quant_error, record_scale, scope,
                      site, suppress, suspended)
from .sentinel import CollapseSentinel, SentinelConfig, SentinelDecision
from .sinks import JsonlWriter, RollingWindow, read_jsonl

__all__ = [
    "UNDERFLOW_ABSMAX", "MetricsCollector", "active", "aggregate", "collect",
    "quant_error_stats", "record", "record_clamp", "record_dge",
    "record_quant_error", "record_scale", "scope", "site", "suppress",
    "suspended",
    "CollapseSentinel", "SentinelConfig", "SentinelDecision",
    "JsonlWriter", "RollingWindow", "read_jsonl",
]
