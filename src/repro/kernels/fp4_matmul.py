"""Pallas TPU kernel: FP4 GeMM with fused dequantization epilogue.

Computes Y = (A_q @ W_q) / (sa x sw) with a single pass over HBM:
  * grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so the
    f32 accumulator tile lives in VMEM scratch across K steps;
  * A_q/W_q tiles are on-grid E2M1 values. On real TPU they arrive as int8
    codes (2x values, formats.to_int8_codes) and the dot runs on the int8
    MXU at 2x bf16 throughput; the /4 code correction is folded into the
    scale epilogue. In interpret mode (CPU validation) the same kernel body
    runs the dot in f32 -- identical results because every E2M1 value is
    exact in both paths;
  * the (1/sa)*(1/sw) outer-product rescale hits the accumulator ONCE at
    the final K step (the paper's Fig. 2 'two scaling factors applied to
    the final result'), not per K-tile.

MXU alignment: bm, bn, bk multiples of 128 (the systolic array edge);
default tiles (256, 256, 512) give a 0.6 MB accumulator and ~1.2 MB of
operand traffic per step -- well inside VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, w_ref, sa_ref, sw_ref, o_ref, acc_ref, *, n_k: int,
                   k_total: int, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)          # (bm, bk) on-grid values
    w = w_ref[...].astype(jnp.float32)          # (bk, bn)
    # Ragged-K masking: the tail tile's out-of-bounds reads are undefined
    # (NaN in interpret mode, garbage on hardware); zero both operands so
    # pad products contribute exactly 0 to the accumulator.
    col = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(col + k_step * bk < k_total, a, 0.0)
    row = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
    w = jnp.where(row + k_step * bk < k_total, w, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        inv = (1.0 / sa_ref[...]) * (1.0 / sw_ref[...])   # (bm,1)*(1,bn)
        o_ref[...] = (acc_ref[...] * inv).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                              "interpret", "out_dtype"))
def fp4_matmul_kernel(a_q: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                      sw: jnp.ndarray, *, block_m: int = 256,
                      block_n: int = 256, block_k: int = 512,
                      interpret: bool = True, out_dtype=jnp.float32):
    """a_q: (M,K) on-grid; w_q: (K,N) on-grid; sa: (M,1); sw: (1,N)."""
    M, K = a_q.shape
    K2, N = w_q.shape
    assert K == K2 and sa.shape == (M, 1) and sw.shape == (1, N)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    n_k = pl.cdiv(K, bk)
    grid = (pl.cdiv(M, bm), pl.cdiv(N, bn), n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k, k_total=K, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_q, w_q, sa, sw)
