"""Pallas TPU kernel: causal flash attention (online softmax).

Grid (B*H, Q_blocks); each step owns a (block_q, D) query tile and loops
over K/V tiles with `jax.lax.fori_loop`, keeping running max/denominator and
the f32 output accumulator in VMEM scratch. Causality skips K-tiles fully
above the diagonal (the loop upper bound depends on the Q-tile index), so
the work is the true ~S^2/2.

This is the beyond-paper perf layer for the attention score/PV stage (the
FP4 paper quantizes only GeMMs against weights; QK^T/PV stay bf16 -- this
kernel reduces their HBM traffic from O(S^2) score materialization to
O(S * D)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    # int ref-indexing (q_ref[0]) breaks interpret-mode discharge on some
    # jax versions; load the (1, bq, D) block and drop the unit dim after
    q = q_ref[...][0].astype(jnp.float32)             # (bq, D); block (1,bq,D)
    D = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    S = k_ref.shape[1]
    n_k = S // block_k
    # causal: last K tile index that overlaps this Q tile
    hi = (qi + 1) * block_q
    n_valid = pl.cdiv(hi, block_k) if causal else n_k

    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(kt, _):
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kt * block_k, block_k),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kt * block_k, block_k),
                            slice(None)))[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            k_pos = kt * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        return ()

    jax.lax.fori_loop(0, n_valid, body, ())
    o_ref[...] = (acc_ref[...] /
                  jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                              "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    block_q: int = 256, block_k: int = 256,
                    causal: bool = True, interpret: bool = True):
    """q,k,v: (B, S, H, D) -> (B, S, H, D). S divisible by block sizes."""
    B, S, H, D = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    # fold B,H into the leading grid axis
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq, block_k=bk,
                          causal=causal),
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
