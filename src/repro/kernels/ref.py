"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantize as q_mod


def fp4_quant_ref(x: jnp.ndarray):
    """Token-wise FP4 quantization. x: (M, K) -> (q on grid (M,K), scale (M,1))."""
    return q_mod.quantize(x, axis=-1)


def fp4_matmul_ref(a_q: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                   sw: jnp.ndarray) -> jnp.ndarray:
    """Dequantizing GeMM: (a_q @ w_q) / (sa x sw) in f32."""
    acc = jnp.matmul(a_q.astype(jnp.float32), w_q.astype(jnp.float32))
    return acc / sa / sw


def _clip(a: jnp.ndarray, lohi: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(a.astype(jnp.float32), lohi[0, 0], lohi[0, 1])


def fused_row_scale_ref(a: jnp.ndarray, lohi: jnp.ndarray,
                        fmt: str = "e2m1") -> jnp.ndarray:
    """Token-wise scales of the clamped activation: (M,K) -> (M,1)."""
    from repro.core import formats
    return q_mod.absmax_scale(_clip(a, lohi), -1,
                              formats.get_format(fmt).max_value)


def fused_quant_matmul_ref(a: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                           sw: jnp.ndarray, lohi: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused forward: quantize clip(a)*sa on the grid, GEMM
    against the pre-quantized weight codes, outer-product rescale."""
    a_q = q_mod.lut_round(_clip(a, lohi) * sa)
    return fp4_matmul_ref(a_q, w_q, sa, sw)


def fused_dgrad_ref(g: jnp.ndarray, w_q: jnp.ndarray,
                    sw: jnp.ndarray) -> jnp.ndarray:
    """dA = g @ (W_q / sw)^T in f32."""
    return jnp.matmul(g.astype(jnp.float32),
                      (w_q.astype(jnp.float32) / sw).T)


def fused_wgrad_ref(a: jnp.ndarray, sa: jnp.ndarray, g: jnp.ndarray,
                    dge_mask: jnp.ndarray, lohi: jnp.ndarray) -> jnp.ndarray:
    """dW = (Q(clip(a)*sa)^T @ (g/sa)) * f'(W*sw)  (paper Eq. 22)."""
    a_q = q_mod.lut_round(_clip(a, lohi) * sa)
    return jnp.matmul(a_q.T, g.astype(jnp.float32) / sa) * dge_mask


def outlier_clamp_ref(x: jnp.ndarray, lo: float, hi: float):
    """Fused clamp + residual. Returns (clamped, residual)."""
    c = jnp.clip(x, lo, hi)
    return c, x - c


def flash_attention_ref(q, k, v, *, causal=True):
    """q,k,v: (B, S, H, D) -> (B, S, H, D), f32 softmax."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
