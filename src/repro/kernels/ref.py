"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantize as q_mod


def fp4_quant_ref(x: jnp.ndarray):
    """Token-wise FP4 quantization. x: (M, K) -> (q on grid (M,K), scale (M,1))."""
    return q_mod.quantize(x, axis=-1)


def fp4_matmul_ref(a_q: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                   sw: jnp.ndarray) -> jnp.ndarray:
    """Dequantizing GeMM: (a_q @ w_q) / (sa x sw) in f32."""
    acc = jnp.matmul(a_q.astype(jnp.float32), w_q.astype(jnp.float32))
    return acc / sa / sw


def outlier_clamp_ref(x: jnp.ndarray, lo: float, hi: float):
    """Fused clamp + residual. Returns (clamped, residual)."""
    c = jnp.clip(x, lo, hi)
    return c, x - c


def flash_attention_ref(q, k, v, *, causal=True):
    """q,k,v: (B, S, H, D) -> (B, S, H, D), f32 softmax."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
