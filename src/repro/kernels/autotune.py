"""Shape -> (bm, bn, bk) block-size autotuner for the Pallas GEMM kernels.

Small on purpose: a JSON-persisted dict from ``op:backend:MxNxK`` to the
best-measured block triple, plus MXU-aligned heuristic defaults for cache
misses. The tuner itself (`autotune`) times real kernel invocations -- on
this CPU container that measures the interpret-mode simulation (ordering
is still meaningful because interpret cost tracks grid-step count), on TPU
it measures the compiled Mosaic kernel.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``. The file is written atomically
(tmp + rename) so concurrent benchmark runs cannot corrupt it. Format:

    {"version": 1,
     "entries": {"fused_fwd:pallas_fused:256x512x256": [128, 128, 128],
                 ...}}

Entries are exact-shape keyed: GEMM shapes in one training run come from a
handful of (d_model, d_ff, vocab) combinations, so the cache stays tiny and
exact keys avoid aliasing a tuned tile onto a shape it was never timed on.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from typing import Callable, Iterable

CACHE_VERSION = 1

# Heuristic defaults per op (clipped to the actual dims at lookup time).
# 128 is the MXU edge; bk larger than bm/bn amortizes the accumulator
# rescale epilogue over more contraction steps.
_HEURISTICS: dict[str, tuple[int, int, int]] = {
    "fused_fwd": (128, 128, 256),
    "fused_dgrad": (128, 128, 256),
    "fused_wgrad": (128, 128, 256),
    "split_matmul": (256, 256, 512),
}
_FALLBACK = (128, 128, 128)

# Candidate grid for active tuning (clipped + deduped per shape).
CANDIDATES: tuple[tuple[int, int, int], ...] = (
    (64, 64, 64), (64, 64, 128), (128, 128, 128), (128, 128, 256),
    (128, 256, 256), (256, 128, 256), (256, 256, 256), (256, 256, 512),
)


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _key(op: str, backend: str, m: int, n: int, k: int) -> str:
    return f"{op}:{backend}:{m}x{n}x{k}"


def _clip(blocks: Iterable[int], dims: tuple[int, int, int]) -> tuple[int, int, int]:
    bm, bn, bk = blocks
    m, n, k = dims
    return (max(1, min(bm, m)), max(1, min(bn, n)), max(1, min(bk, k)))


class AutotuneCache:
    """JSON-backed shape->blocks store. Thread-safe; lazy-loaded."""

    def __init__(self, path: str | None = None):
        self._path = path
        self._entries: dict[str, list[int]] | None = None
        self._lock = threading.Lock()

    @property
    def path(self) -> str:
        return self._path or default_cache_path()

    def _load(self) -> dict[str, list[int]]:
        if self._entries is None:
            self._entries = self._read_validated()
        return self._entries

    def _read_validated(self) -> dict[str, list[int]]:
        """Parse + schema-check the cache file; empty dict on any damage.

        A corrupt or foreign-version cache must never take training down
        (DESIGN.md §15) -- the heuristic defaults are always a safe
        fallback, so every damage mode degrades to a cold cache with one
        warning: unreadable file, non-JSON bytes, a JSON value that is
        not our schema (top-level non-dict, wrong version, entries that
        are not 3-vectors of positive ints).
        """
        try:
            with open(self.path) as f:
                data = json.load(f)
        except FileNotFoundError:
            return {}
        except (OSError, ValueError) as e:
            self._warn(f"unreadable autotune cache ({e})")
            return {}
        if not isinstance(data, dict):
            self._warn(f"autotune cache is not an object "
                       f"(got {type(data).__name__})")
            return {}
        if data.get("version") != CACHE_VERSION:
            self._warn(f"autotune cache version {data.get('version')!r} "
                       f"!= {CACHE_VERSION}")
            return {}
        entries = data.get("entries")
        if not isinstance(entries, dict):
            self._warn("autotune cache has no entries dict")
            return {}
        good, bad = {}, 0
        for k, v in entries.items():
            if (isinstance(k, str) and isinstance(v, list) and len(v) == 3
                    and all(isinstance(b, int) and b > 0 for b in v)):
                good[k] = v
            else:
                bad += 1
        if bad:
            self._warn(f"dropped {bad} malformed autotune entries")
        return good

    def _warn(self, why: str) -> None:
        warnings.warn(f"{why}; starting with an empty autotune cache "
                      f"[{self.path}]", stacklevel=3)

    def _save(self) -> None:
        path = self.path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": self._entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def get(self, op: str, backend: str, m: int, n: int,
            k: int) -> tuple[int, int, int] | None:
        with self._lock:
            hit = self._load().get(_key(op, backend, m, n, k))
        if hit is None:
            return None
        return _clip(hit, (m, n, k))

    def put(self, op: str, backend: str, m: int, n: int, k: int,
            blocks: tuple[int, int, int]) -> None:
        with self._lock:
            self._load()[_key(op, backend, m, n, k)] = list(blocks)
            self._save()


_GLOBAL = AutotuneCache()


def get_blocks(op: str, m: int, n: int, k: int, *,
               backend: str = "pallas_fused",
               cache: AutotuneCache | None = None) -> tuple[int, int, int]:
    """Cached blocks for (op, shape), else the clipped heuristic default.

    Never tunes -- lookup is pure and cheap enough for the hot path.
    """
    cache = cache or _GLOBAL
    hit = cache.get(op, backend, m, n, k)
    if hit is not None:
        return hit
    return _clip(_HEURISTICS.get(op, _FALLBACK), (m, n, k))


def autotune(op: str, make_fn: Callable[[int, int, int], Callable[[], object]],
             m: int, n: int, k: int, *, backend: str = "pallas_fused",
             candidates: Iterable[tuple[int, int, int]] | None = None,
             iters: int = 3,
             cache: AutotuneCache | None = None) -> tuple[tuple[int, int, int], float]:
    """Time every candidate block triple and persist the fastest.

    `make_fn(bm, bn, bk)` returns a zero-arg callable running the kernel to
    completion (caller is responsible for block_until_ready). Returns
    (best_blocks, best_seconds_per_call). Candidates that fail to build or
    run (e.g. VMEM overflow on real TPU) are skipped.
    """
    cache = cache or _GLOBAL
    cands = list(dict.fromkeys(
        _clip(c, (m, n, k)) for c in (candidates or CANDIDATES)))
    best: tuple[int, int, int] | None = None
    best_t = float("inf")
    for blocks in cands:
        try:
            fn = make_fn(*blocks)
            fn()  # compile / warm up
            t0 = time.perf_counter()
            for _ in range(iters):
                fn()
            t = (time.perf_counter() - t0) / iters
        except Exception:  # noqa: BLE001 -- skip infeasible tile configs
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        raise RuntimeError(f"autotune: no feasible candidate for {op} "
                           f"{m}x{n}x{k}")
    cache.put(op, backend, m, n, k, best)
    return best, best_t
