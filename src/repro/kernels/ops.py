"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the kernel body
executes in Python -- bit-faithful validation of the TPU program); on real
TPU `interpret=False` compiles to Mosaic. `INTERPRET` flips automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import autotune as _at
from . import flash_attention as _fa
from . import fp4_fused as _fused
from . import fp4_matmul as _mm
from . import fp4_quant as _q
from . import outlier as _ol

INTERPRET = jax.default_backend() == "cpu"


def fp4_quantize(x: jnp.ndarray, block_m: int = 256):
    """Token-wise E2M1 quantization: (M,K) -> (q, scale (M,1)).

    When an obs collector is active, kernel quant-health stats (SNR, scale
    extrema, underflow) are recorded under a "pallas_quant" site. The
    stats are computed *outside* the jitted kernel so the recorded scalars
    live at the caller's trace level (see repro/obs/collect.py).
    """
    q, s = _q.fp4_quant(x, block_m=block_m, interpret=INTERPRET)
    from repro import obs
    if obs.active() is not None:
        with obs.site("pallas_quant"):
            for key, val in _q.quant_stats(x, q, s).items():
                obs.record(key, val)
    return q, s


def fp4_matmul_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                      sa: jnp.ndarray | None = None,
                      sw: jnp.ndarray | None = None, **kw):
    """Fused dequantizing GeMM. When called from core.fp4_gemm the rescale
    is applied outside, so identity scales are used here."""
    M, K = a_q.shape
    N = w_q.shape[1]
    if sa is None:
        sa = jnp.ones((M, 1), jnp.float32)
    if sw is None:
        sw = jnp.ones((1, N), jnp.float32)
    orig_shape = None
    if a_q.ndim > 2:
        orig_shape = a_q.shape
        a_q = a_q.reshape(-1, K)
    out = _mm.fp4_matmul_kernel(a_q, w_q, sa, sw, interpret=INTERPRET, **kw)
    if orig_shape is not None:
        out = out.reshape(*orig_shape[:-1], N)
    return out


def _blocks(op: str, M: int, N: int, K: int,
            blocks: tuple[int, int, int] | None) -> tuple[int, int, int]:
    """Explicit blocks win; else the autotune cache / heuristic default."""
    if blocks is not None:
        return blocks
    return _at.get_blocks(op, M, N, K)


def fused_row_scale(a: jnp.ndarray, lohi: jnp.ndarray | None = None, *,
                    fmt: str = "e2m1", block_m: int = 256,
                    block_k: int = 512) -> jnp.ndarray:
    """Token-wise absmax scales of clip(a): (M,K) -> (M,1). The cheap
    pre-pass of the fused pipeline (reads A, writes M floats)."""
    if lohi is None:
        lohi = _fused.no_clamp_bounds()
    return _fused.fused_row_scale(a, lohi, block_m=block_m, block_k=block_k,
                                  interpret=INTERPRET, fmt=fmt)


def fp4_matmul_fused(a: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                     sw: jnp.ndarray, lohi: jnp.ndarray | None = None, *,
                     fmt: str = "e2m1",
                     blocks: tuple[int, int, int] | None = None):
    """Fused clamp+quantize+GEMM+rescale forward (kernels/fp4_fused.py).

    `a` is the RAW activation -- quantization happens inside the K-loop; no
    A_q round-trips HBM. When an obs collector is active, quant-health
    stats of the in-kernel quantization are recorded under a
    "pallas_fused_quant" site via a jnp recompute of the (cheap,
    elementwise) quantizer -- the fused kernel itself stays stats-free.
    """
    if lohi is None:
        lohi = _fused.no_clamp_bounds()
    M, K = a.shape
    N = w_q.shape[1]
    bm, bn, bk = _blocks("fused_fwd", M, N, K, blocks)
    out = _fused.fused_quant_matmul(a, w_q, sa, sw, lohi, block_m=bm,
                                    block_n=bn, block_k=bk,
                                    interpret=INTERPRET, fmt=fmt)
    from repro import obs
    if obs.active() is not None:
        from repro.core import quantize as _qz
        a_c = jnp.clip(a.astype(jnp.float32), lohi[0, 0], lohi[0, 1])
        q = _qz.lut_round(a_c * sa, fmt)
        with obs.site("pallas_fused_quant"):
            for key, val in _q.quant_stats(a_c, q, sa).items():
                obs.record(key, val)
    return out


def fp4_dgrad_fused(g: jnp.ndarray, w_q: jnp.ndarray, sw: jnp.ndarray, *,
                    blocks: tuple[int, int, int] | None = None):
    """dA = g @ (W_q/sw)^T with the dequant fold-in fused on the g tile."""
    M, N = g.shape
    K = w_q.shape[0]
    bm, bn, bk = _blocks("fused_dgrad", M, N, K, blocks)
    return _fused.fused_dgrad(g, w_q, sw, block_m=bm, block_n=bn, block_k=bk,
                              interpret=INTERPRET)


def fp4_wgrad_fused(a: jnp.ndarray, sa: jnp.ndarray, g: jnp.ndarray,
                    dge_mask: jnp.ndarray, lohi: jnp.ndarray | None = None, *,
                    fmt: str = "e2m1",
                    blocks: tuple[int, int, int] | None = None):
    """dW = (Q(clip(a)*sa)^T @ (g/sa)) * dge_mask, re-quantizing the
    activation tile-by-tile inside the contraction loop (paper Eq. 22)."""
    if lohi is None:
        lohi = _fused.no_clamp_bounds()
    M, K = a.shape
    N = g.shape[1]
    bm, bn, bk = _blocks("fused_wgrad", K, N, M, blocks)
    return _fused.fused_wgrad(a, sa, g, dge_mask, lohi, block_m=bm,
                              block_n=bn, block_k=bk, interpret=INTERPRET,
                              fmt=fmt)


def outlier_clamp(x: jnp.ndarray, lo, hi, block_m: int = 256):
    return _ol.outlier_clamp(x, jnp.asarray(lo), jnp.asarray(hi),
                             block_m=block_m, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=INTERPRET)
