"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the kernel body
executes in Python -- bit-faithful validation of the TPU program); on real
TPU `interpret=False` compiles to Mosaic. `INTERPRET` flips automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import fp4_matmul as _mm
from . import fp4_quant as _q
from . import outlier as _ol

INTERPRET = jax.default_backend() == "cpu"


def fp4_quantize(x: jnp.ndarray, block_m: int = 256):
    """Token-wise E2M1 quantization: (M,K) -> (q, scale (M,1)).

    When an obs collector is active, kernel quant-health stats (SNR, scale
    extrema, underflow) are recorded under a "pallas_quant" site. The
    stats are computed *outside* the jitted kernel so the recorded scalars
    live at the caller's trace level (see repro/obs/collect.py).
    """
    q, s = _q.fp4_quant(x, block_m=block_m, interpret=INTERPRET)
    from repro import obs
    if obs.active() is not None:
        with obs.site("pallas_quant"):
            for key, val in _q.quant_stats(x, q, s).items():
                obs.record(key, val)
    return q, s


def fp4_matmul_pallas(a_q: jnp.ndarray, w_q: jnp.ndarray,
                      sa: jnp.ndarray | None = None,
                      sw: jnp.ndarray | None = None, **kw):
    """Fused dequantizing GeMM. When called from core.fp4_gemm the rescale
    is applied outside, so identity scales are used here."""
    M, K = a_q.shape
    N = w_q.shape[1]
    if sa is None:
        sa = jnp.ones((M, 1), jnp.float32)
    if sw is None:
        sw = jnp.ones((1, N), jnp.float32)
    orig_shape = None
    if a_q.ndim > 2:
        orig_shape = a_q.shape
        a_q = a_q.reshape(-1, K)
    out = _mm.fp4_matmul_kernel(a_q, w_q, sa, sw, interpret=INTERPRET, **kw)
    if orig_shape is not None:
        out = out.reshape(*orig_shape[:-1], N)
    return out


def outlier_clamp(x: jnp.ndarray, lo, hi, block_m: int = 256):
    return _ol.outlier_clamp(x, jnp.asarray(lo), jnp.asarray(hi),
                             block_m=block_m, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=INTERPRET)
