"""Pallas TPU kernel: fused outlier clamp + residual extraction (OCC §3.2).

One pass over the activation tile in VMEM produces both the clamped tensor
(FP4 GeMM input) and the sparse residual (compensation input) -- the
unfused jnp version reads x twice from HBM. Thresholds are scalars
(prefetched, SMEM-resident on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _clamp_kernel(x_ref, lo_ref, hi_ref, c_ref, r_ref):
    x = x_ref[...]
    lo = lo_ref[0, 0].astype(x.dtype)
    hi = hi_ref[0, 0].astype(x.dtype)
    c = jnp.clip(x, lo, hi)
    c_ref[...] = c
    r_ref[...] = x - c


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def outlier_clamp(x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, *,
                  block_m: int = 256, interpret: bool = True):
    """x: (M, K); lo/hi scalar thresholds -> (clamped, residual)."""
    M, K = x.shape
    bm = min(block_m, M)
    lo2 = jnp.reshape(lo.astype(jnp.float32), (1, 1))
    hi2 = jnp.reshape(hi.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _clamp_kernel,
        grid=(pl.cdiv(M, bm),),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), x.dtype),
                   jax.ShapeDtypeStruct((M, K), x.dtype)],
        interpret=interpret,
    )(x, lo2, hi2)
