"""Pallas TPU kernel: token-wise FP4 (E2M1) quantization.

Port of the paper's CUDA LUT kernel (App. A) to the TPU memory hierarchy:
instead of one thread per element, each grid step processes a (BLOCK_M, K)
tile resident in VMEM; the absmax reduction, scaling, and the 15-way
threshold chain are 8x128-lane vector ops. The threshold chain is expressed
as a sum of comparisons against the interval boundaries (a searchsorted in
vector form) followed by a gather from the 15-entry value table held in
VMEM -- no divergent control flow, MXU-free.

Outputs the *scaled* on-grid tensor plus per-row scales, matching
core.quantize.quantize(x, axis=-1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import formats

_VALUES = np.asarray(formats.E2M1.values, np.float32)        # (15,)
_BOUNDS = np.asarray(formats.E2M1.boundaries, np.float32)    # (14,)
FP4_MAX = formats.E2M1.max_value


_DELTAS = np.diff(_VALUES)  # value step across each boundary (14 scalars)


# Denormal floor mirrored from core.quantize.absmax_scale: rows whose
# absmax is below it would overflow the f32 scale (6/1.2e-38 = inf) and
# carry no 4-bit-representable signal; their scale is forced to 1 so the
# kernel matches the reference bit-for-bit on denormal inputs.
_ABSMAX_FLOOR = 1e-30


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                       # (bm, K)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)       # (bm, 1)
    scale = FP4_MAX / jnp.where(amax > _ABSMAX_FLOOR, amax, FP4_MAX)
    xs = x * scale
    # LUT as a threshold-delta accumulation (no gather, pure vector ops):
    # value = v_min + sum_i (v[i+1]-v[i]) * (xs > bound_i). All boundaries
    # and deltas are Python floats -> scalar immediates in the kernel.
    # '>=' matches searchsorted(side="right"): a value exactly on a boundary
    # rounds away from zero, like the reference LUT.
    q = jnp.full(xs.shape, float(_VALUES[0]), jnp.float32)
    for b, d in zip(_BOUNDS, _DELTAS):
        q = q + float(d) * (xs >= float(b)).astype(jnp.float32)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = scale.astype(s_ref.dtype)


def quant_stats(x: jnp.ndarray, q: jnp.ndarray,
                scale: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """Quant-health of one kernel invocation (pure jnp, computed *outside*
    the Pallas/jit body so the scalars live at the caller's trace level):
    dequantization MSE/SNR plus the per-row scale extrema and underflow
    fraction. Feeds obs recording (kernels/ops.py) and BENCH columns."""
    from repro import obs as _obs  # local: keep kernel import cost minimal

    xf = x.astype(jnp.float32)
    stats = _obs.quant_error_stats(xf, q.astype(jnp.float32) / scale)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    stats["scale_min"] = jnp.min(scale)
    stats["scale_max"] = jnp.max(scale)
    stats["underflow_frac"] = jnp.mean(
        (amax <= _obs.UNDERFLOW_ABSMAX).astype(jnp.float32))
    return stats


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def fp4_quant(x: jnp.ndarray, *, block_m: int = 256,
              interpret: bool = True):
    """x: (M, K) -> (q (M,K) on-grid, scale (M,1) f32). K is kept whole per
    tile (row reduction needs the full row; K*block_m*4B must fit VMEM --
    block_m=256, K=8192 -> 8 MB, within the ~16 MB v5e VMEM budget with
    double buffering disabled for this elementwise kernel)."""
    M, K = x.shape
    bm = min(block_m, M)
    grid = (pl.cdiv(M, bm),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), x.dtype),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)
