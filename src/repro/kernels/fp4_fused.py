"""Fused Pallas FP4 pipeline: clamp -> scale -> E2M1 quantize -> GEMM ->
rescale in ONE pass over the activation (DESIGN.md §12).

The split path (kernels/fp4_quant.py + kernels/fp4_matmul.py) costs three
HBM round trips over A: the OCC clamp writes A_c, the quantizer reads A_c
and writes A_q, the GEMM reads A_q. Here the clamp + token-wise scaling +
the 15-way threshold chain run *inside* the GEMM's K-loop on the VMEM-
resident activation tile, so the full-size tensor crosses HBM once (the
row-scale pre-pass reads A too, but writes only M floats -- see §12 for
the traffic accounting). Weights arrive pre-quantized on the E2M1 grid
(codes); the (1/sa)(1/sw) outer-product rescale hits the f32 accumulator
once, in the final-K-step epilogue.

Four kernels:
  * `_row_scale_kernel`  -- K-tiled row absmax of clip(A) -> sa (M,1),
                            same underflow-floor semantics as
                            core.quantize.absmax_scale;
  * `_fused_fwd_kernel`  -- the fused quantize+GEMM described above;
  * `_dgrad_kernel`      -- dA = g @ (W_q/sw)^T with the 1/sw fold-in on
                            the g tile (STE through activation quant);
  * `_wgrad_kernel`      -- dW = Q(clip(A)*sa)^T @ (g/sa), DGE derivative
                            mask applied in the epilogue (paper Eq. 22).
                            The activation is RE-quantized in-kernel from
                            the raw tile, so the backward also never reads
                            a materialized A_q.

Ragged tiles: every grid axis uses `pl.cdiv`; out-of-bounds *writes* are
masked by Pallas, but out-of-bounds *reads* are undefined (NaN-filled in
interpret mode, garbage on hardware), so each kernel masks its contraction
tail explicitly -- the threshold chain maps any pad value (NaN/inf
included) onto the finite grid, and the opposing operand tile is zeroed,
making pad products exactly 0.

All kernels run in interpret mode on CPU (bit-faithful validation) and
compile to Mosaic on TPU; block sizes come from kernels/autotune.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats

# Mirrors core.quantize.absmax_scale: rows whose absmax is below this carry
# no 4-bit-representable signal; their scale is forced to 1.
_ABSMAX_FLOOR = 1e-30


@functools.lru_cache(maxsize=None)
def _chain(fmt_name: str):
    """(v0, ((bound, delta), ...)) Python-float constants of the format's
    threshold chain -- scalar immediates inside the kernels."""
    fmt = formats.FORMATS[fmt_name]
    values = np.asarray(fmt.values, np.float64)
    bounds = np.asarray(fmt.boundaries, np.float64)
    deltas = np.diff(values)
    return float(values[0]), tuple(
        (float(b), float(d)) for b, d in zip(bounds, deltas))


def _round_to_grid(xs: jnp.ndarray, fmt_name: str) -> jnp.ndarray:
    """Round-to-nearest on the format grid as a threshold-delta accumulation
    (vector ops only; `>=` matches searchsorted(side="right") tie-breaking).
    Any non-finite input lands on a finite grid value: NaN compares False
    everywhere (-> v_min), +inf True everywhere (-> v_max)."""
    v0, steps = _chain(fmt_name)
    q = jnp.full(xs.shape, v0, jnp.float32)
    for b, d in steps:
        q = q + d * (xs >= b).astype(jnp.float32)
    return q


def _clamp(x: jnp.ndarray, lohi_ref) -> jnp.ndarray:
    """clip(x, lo, hi) with lo/hi from the (1,2) bounds operand. With
    lo=-inf/hi=+inf this is the identity (the no-OCC arms)."""
    return jnp.minimum(jnp.maximum(x, lohi_ref[0, 0]), lohi_ref[0, 1])


def _tail_mask(shape, axis: int, step, block: int, total: int):
    """Validity mask for a contraction-axis tile: True where the global
    index `step*block + local` is inside the real extent `total`."""
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis)
    return idx + step * block < total


# ---------------------------------------------------------------------------
# Row-scale pre-pass: sa = MAX / absmax(clip(A), axis=-1)
# ---------------------------------------------------------------------------

def _row_scale_kernel(a_ref, lohi_ref, s_ref, amax_ref, *, n_k, k_total, bk,
                      max_value):
    k_step = pl.program_id(1)

    @pl.when(k_step == 0)
    def _init():
        amax_ref[...] = jnp.zeros_like(amax_ref)

    x = _clamp(a_ref[...].astype(jnp.float32), lohi_ref)
    x = jnp.where(_tail_mask(x.shape, 1, k_step, bk, k_total),
                  jnp.abs(x), 0.0)
    amax_ref[...] = jnp.maximum(amax_ref[...],
                                jnp.max(x, axis=-1, keepdims=True))

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        amax = amax_ref[...]
        s_ref[...] = max_value / jnp.where(amax > _ABSMAX_FLOOR, amax,
                                           max_value)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k",
                                             "interpret", "fmt"))
def fused_row_scale(a: jnp.ndarray, lohi: jnp.ndarray, *, block_m: int = 256,
                    block_k: int = 512, interpret: bool = True,
                    fmt: str = "e2m1") -> jnp.ndarray:
    """a: (M, K), lohi: (1, 2) f32 clamp bounds -> token-wise scales (M, 1).

    Bandwidth: reads A once, writes M floats. K is tiled (unlike
    kernels/fp4_quant.py which keeps rows whole), so arbitrarily long rows
    stay inside VMEM.
    """
    M, K = a.shape
    bm, bk = min(block_m, M), min(block_k, K)
    n_k = pl.cdiv(K, bk)
    return pl.pallas_call(
        functools.partial(_row_scale_kernel, n_k=n_k, k_total=K, bk=bk,
                          max_value=formats.get_format(fmt).max_value),
        grid=(pl.cdiv(M, bm), n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((1, 2), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(a, lohi)


# ---------------------------------------------------------------------------
# Fused forward: Y = (Q(clip(A)*sa) @ W_q) / (sa x sw)
# ---------------------------------------------------------------------------

def _fused_fwd_kernel(a_ref, w_ref, sa_ref, sw_ref, lohi_ref, o_ref, acc_ref,
                      *, n_k, k_total, bk, fmt_name):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _clamp(a_ref[...].astype(jnp.float32), lohi_ref)      # (bm, bk)
    q = _round_to_grid(a * sa_ref[...], fmt_name)             # on-grid
    # Contraction-tail masking: zero BOTH operands so pad products are 0
    # even when the opposing pad is non-finite.
    q = jnp.where(_tail_mask(q.shape, 1, k_step, bk, k_total), q, 0.0)
    w = w_ref[...].astype(jnp.float32)                        # (bk, bn)
    w = jnp.where(_tail_mask(w.shape, 0, k_step, bk, k_total), w, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        q, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _epilogue():
        inv = (1.0 / sa_ref[...]) * (1.0 / sw_ref[...])       # (bm,1)*(1,bn)
        o_ref[...] = (acc_ref[...] * inv).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "fmt", "out_dtype"))
def fused_quant_matmul(a: jnp.ndarray, w_q: jnp.ndarray, sa: jnp.ndarray,
                       sw: jnp.ndarray, lohi: jnp.ndarray, *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 256, interpret: bool = True,
                       fmt: str = "e2m1", out_dtype=jnp.float32):
    """a: (M,K) RAW activation; w_q: (K,N) on-grid; sa: (M,1); sw: (1,N);
    lohi: (1,2) clamp bounds. One HBM pass over `a`; no A_q materialized."""
    M, K = a.shape
    K2, N = w_q.shape
    assert K == K2 and sa.shape == (M, 1) and sw.shape == (1, N)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    n_k = pl.cdiv(K, bk)
    return pl.pallas_call(
        functools.partial(_fused_fwd_kernel, n_k=n_k, k_total=K, bk=bk,
                          fmt_name=fmt),
        grid=(pl.cdiv(M, bm), pl.cdiv(N, bn), n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, w_q, sa, sw, lohi)


# ---------------------------------------------------------------------------
# Fused dgrad: dA = g @ (W_q / sw)^T
# ---------------------------------------------------------------------------

def _dgrad_kernel(g_ref, w_ref, sw_ref, o_ref, acc_ref, *, n_n, n_total, bn):
    n_step = pl.program_id(2)

    @pl.when(n_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32) * (1.0 / sw_ref[...])  # (bm, bn)
    g = jnp.where(_tail_mask(g.shape, 1, n_step, bn, n_total), g, 0.0)
    w = w_ref[...].astype(jnp.float32)                        # (bkK, bn)
    w = jnp.where(_tail_mask(w.shape, 1, n_step, bn, n_total), w, 0.0)
    # contract over N: (bm, bn) x (bkK, bn) -> (bm, bkK)
    acc_ref[...] += jax.lax.dot_general(
        g, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(n_step == n_n - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def fused_dgrad(g: jnp.ndarray, w_q: jnp.ndarray, sw: jnp.ndarray, *,
                block_m: int = 128, block_n: int = 256, block_k: int = 128,
                interpret: bool = True, out_dtype=jnp.float32):
    """g: (M,N) upstream cotangent; w_q: (K,N) on-grid; sw: (1,N).
    Returns dA (M,K) = g @ W_dq^T with the dequant fold-in fused on the g
    tile (sa cancels exactly -- STE, see core/fp4_gemm.py docstring)."""
    M, N = g.shape
    K, N2 = w_q.shape
    assert N == N2 and sw.shape == (1, N)
    bm, bk, bn = min(block_m, M), min(block_k, K), min(block_n, N)
    n_n = pl.cdiv(N, bn)
    return pl.pallas_call(
        functools.partial(_dgrad_kernel, n_n=n_n, n_total=N, bn=bn),
        grid=(pl.cdiv(M, bm), pl.cdiv(K, bk), n_n),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, j, n: (j, n)),
            pl.BlockSpec((1, bn), lambda i, j, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g, w_q, sw)


# ---------------------------------------------------------------------------
# Fused wgrad: dW = (Q(clip(A)*sa)^T @ (g/sa)) * dge_mask   (paper Eq. 22)
# ---------------------------------------------------------------------------

def _wgrad_kernel(a_ref, sa_ref, g_ref, mask_ref, lohi_ref, o_ref, acc_ref,
                  *, n_m, m_total, bmc, fmt_name):
    m_step = pl.program_id(2)

    @pl.when(m_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = _clamp(a_ref[...].astype(jnp.float32), lohi_ref)      # (bmc, bkO)
    q = _round_to_grid(a * sa_ref[...], fmt_name)
    valid = _tail_mask(q.shape, 0, m_step, bmc, m_total)
    q = jnp.where(valid, q, 0.0)
    g = g_ref[...].astype(jnp.float32) * (1.0 / sa_ref[...])  # (bmc, bnO)
    g = jnp.where(_tail_mask(g.shape, 0, m_step, bmc, m_total), g, 0.0)
    # contract over M: (bmc, bkO) x (bmc, bnO) -> (bkO, bnO)
    acc_ref[...] += jax.lax.dot_general(
        q, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(m_step == n_m - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] * mask_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "fmt", "out_dtype"))
def fused_wgrad(a: jnp.ndarray, sa: jnp.ndarray, g: jnp.ndarray,
                dge_mask: jnp.ndarray, lohi: jnp.ndarray, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 256,
                interpret: bool = True, fmt: str = "e2m1",
                out_dtype=jnp.float32):
    """a: (M,K) RAW activation; sa: (M,1); g: (M,N) cotangent;
    dge_mask: (K,N) = f'(W*sw) (ones for STE); lohi: (1,2).

    Returns dW (K,N). The activation is re-quantized tile-by-tile inside
    the contraction loop (identical chain to the forward), so neither pass
    ever materializes A_q in HBM. The DGE derivative mask multiplies the
    accumulator once, in the epilogue. sw cancels (App. C.2).
    """
    M, K = a.shape
    M2, N = g.shape
    assert M == M2 and sa.shape == (M, 1) and dge_mask.shape == (K, N)
    bkO, bnO, bmc = min(block_m, K), min(block_n, N), min(block_k, M)
    n_m = pl.cdiv(M, bmc)
    return pl.pallas_call(
        functools.partial(_wgrad_kernel, n_m=n_m, m_total=M, bmc=bmc,
                          fmt_name=fmt),
        grid=(pl.cdiv(K, bkO), pl.cdiv(N, bnO), n_m),
        in_specs=[
            pl.BlockSpec((bmc, bkO), lambda i, j, m: (m, i)),
            pl.BlockSpec((bmc, 1), lambda i, j, m: (m, 0)),
            pl.BlockSpec((bmc, bnO), lambda i, j, m: (m, j)),
            pl.BlockSpec((bkO, bnO), lambda i, j, m: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j, m: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bkO, bnO), lambda i, j, m: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bkO, bnO), jnp.float32)],
        interpret=interpret,
    )(a, sa, g, dge_mask, lohi)


def no_clamp_bounds() -> jnp.ndarray:
    """(1,2) bounds that make the in-kernel clamp the identity."""
    return jnp.asarray([[-jnp.inf, jnp.inf]], jnp.float32)
