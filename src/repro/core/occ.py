"""Outlier Clamping and Compensation (paper §3.2).

Activations are clamped to their (1-alpha, alpha) quantiles before FP4
quantization; the residual Delta = A - A_c (0.2%..2% non-zeros at
alpha in [0.99, 0.999]) is compensated with a high-precision matmul:

    Y = FP4GeMM(A_c, W) + Delta @ W        (paper Eq. 9 + compensation)

Clamp thresholds are computed from the *current* tensor (dynamic, no
calibration set -- paper §5 "Handling Outliers").

Threshold modes (QuantPolicy.occ_threshold):
  * "exact"  -- jnp.quantile over the full tensor (faithful reference;
                a full sort, expensive at 32K+ sequence lengths).
  * "sample" -- quantile of a fixed-size deterministic sample (production
                path; error ~ O(1/sqrt(n)) on the quantile estimate and the
                residual path compensates any misestimate exactly, because
                Delta is *defined* as A - clamp(A) for whatever threshold
                was chosen).

Compensation modes (QuantPolicy.occ_comp):
  * "dense"   -- Delta kept as a (mostly zero) dense tensor; matmul in bf16.
                 Faithful to the paper's sparse GeMM semantics (bit-exact
                 result) -- on TPU there is no sparse MXU, so the reference
                 path is a masked dense GeMM.
  * "channel" -- TPU-native adaptation: outliers are channel-structured
                 (paper App. D); pick the top-k outlier channels by residual
                 mass and compensate with a skinny dense GeMM over only
                 those channels. k = ceil(2*(1-alpha)*C) channels keeps the
                 FLOP overhead at the paper's 2(1-alpha) budget. Off-channel
                 outliers are folded back into the clamped tensor (they are
                 re-clamped, bounded error) -- documented deviation.
  * "none"    -- clamp only (Table 1 row 2 ablation).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

_SAMPLE = 65536


def _strided_sample(x: jnp.ndarray, target: int) -> jnp.ndarray:
    """Deterministic strided sample of ~`target` elements taken along the
    tensor's own dims (never flattens the full tensor first -- a flatten of
    a sharded activation forces an all-gather under GSPMD)."""
    for axis in range(x.ndim):
        if x.size <= target:
            break
        need = -(-x.size // target)                  # remaining reduction
        stride = min(x.shape[axis], need)
        if stride > 1:
            idx = (slice(None),) * axis + (slice(None, None, stride),)
            x = x[idx]
    return x.reshape(-1)


def quantile_thresholds(x: jnp.ndarray, alpha: float,
                        mode: str = "exact") -> tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, hi) clamp thresholds = (1-alpha, alpha) quantiles of x (signed,
    per paper Eq. 9)."""
    if mode == "sample" and x.size > _SAMPLE:
        xf = _strided_sample(x.astype(jnp.float32), _SAMPLE)
    else:
        xf = x.astype(jnp.float32).reshape(-1)
    qs = jnp.quantile(xf, jnp.asarray([1.0 - alpha, alpha], jnp.float32))
    return qs[0], qs[1]


def clamp_and_residual(x: jnp.ndarray, alpha: float, mode: str = "exact"):
    """x -> (x_clamped, residual) with x == x_clamped + residual exactly.

    Thresholds are treated as constants (stop_gradient): gradient flows with
    slope 1 everywhere through the x_c + residual sum, matching the identity
    A == A_c + Delta.
    """
    lo, hi = quantile_thresholds(jax.lax.stop_gradient(x), alpha, mode)
    x_c = jnp.clip(x, lo.astype(x.dtype), hi.astype(x.dtype))
    return x_c, x - x_c


def topk_outlier_channels(residual: jnp.ndarray, num_channels: int):
    """Indices of the `num_channels` columns with largest residual mass.

    residual: (..., C). Returns (idx[num_channels], mass_fraction scalar) --
    mass_fraction reports how much of the total |residual| the selected
    channels capture (diagnostics for the channel-compensation deviation).
    """
    mass = jnp.sum(jnp.abs(residual.astype(jnp.float32)),
                   axis=tuple(range(residual.ndim - 1)))
    total = jnp.sum(mass) + 1e-12
    _, idx = jax.lax.top_k(mass, num_channels)
    captured = jnp.sum(mass[idx]) / total
    return idx, captured


def channel_compensation(residual: jnp.ndarray, w: jnp.ndarray,
                         num_channels: int) -> jnp.ndarray:
    """Skinny dense GeMM over the top-k outlier channels (TPU OCC path).

    residual: (..., C_in), w: (C_in, C_out). Gathers the k worst channels of
    the residual and the matching rows of w; cost 2*T*k*C_out FLOPs.
    """
    idx, _ = topk_outlier_channels(residual, num_channels)
    r_sel = jnp.take(residual, idx, axis=-1)           # (..., k)
    w_sel = jnp.take(w, idx, axis=0)                   # (k, C_out)
    return r_sel @ w_sel


def occ_metrics(x: jnp.ndarray, x_hat: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """Cosine similarity / MSE / SNR between original and reconstructed
    tensors (paper Table 1 metrics)."""
    a = x.astype(jnp.float32).reshape(-1)
    b = x_hat.astype(jnp.float32).reshape(-1)
    cos = jnp.dot(a, b) / jnp.maximum(jnp.linalg.norm(a) * jnp.linalg.norm(b), 1e-12)
    mse = jnp.mean((a - b) ** 2)
    snr = 10.0 * jnp.log10(jnp.mean(a ** 2) / jnp.maximum(mse, 1e-20))
    return {"sim": cos, "mse": mse, "snr": snr}
