"""Core FP4 training library (the paper's contribution, in JAX).

Public surface:
  formats   -- E2M1/E1M2/E3M0 grids, int8 exactness, 4-bit packing
  quantize  -- absmax vector-wise LUT quantization (+ fp8 helpers)
  dge       -- Differentiable Gradient Estimator custom_vjp (paper §3.1)
  occ       -- Outlier Clamping & Compensation (paper §3.2)
  fp4_gemm  -- FP4 GeMM with vector-wise rescale + backends
  linear    -- fp4_linear layer (OCC + GeMM + compensation + bias)
  policy    -- QuantPolicy presets (paper Fig. 6 experimental arms)
"""
from . import dge, formats, occ, policy, quantize
from .fp4_gemm import fp4_matmul
from .linear import fp4_linear
from .policy import PRESETS, QuantPolicy, get_policy

__all__ = [
    "dge", "formats", "occ", "policy", "quantize",
    "fp4_matmul", "fp4_linear", "PRESETS", "QuantPolicy", "get_policy",
]
