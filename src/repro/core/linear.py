"""FP4 linear layer: OCC on the activation, FP4 GeMM, compensation, bias.

    y = FP4GeMM(clamp(a), w) + compensate(a - clamp(a), w) + b

This is the unit the paper drops into every Transformer GeMM site (QKV, O,
MLP up/down, expert FFNs, MLA projections, SSM in/out projections, ...).
The compensation path runs in bf16 ("high precision sparse" in the paper;
masked-dense or top-k-channel skinny GeMM on TPU -- see core/occ.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import obs

from . import fp4_gemm
from . import occ as occ_mod
from .fp4_gemm import fp4_matmul
from .policy import QuantPolicy


def fp4_linear(a: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
               *, policy: QuantPolicy, name: str | None = None) -> jnp.ndarray:
    """a: (..., K), w: (K, N), optional bias (N,).

    `name` labels this GeMM site in the quant-health records when
    `policy.obs_metrics` is on (auto-numbered "siteN" otherwise); it has
    no effect on the computation.
    """
    if not policy.enabled:
        y = jnp.matmul(a, w, preferred_element_type=jnp.float32)
        y = y.astype(policy.compute_dtype)
        return y + b.astype(y.dtype) if b is not None else y

    with obs.site(name) if policy.obs_metrics else _NULL_CTX as rec:
        if policy.occ and policy.a_quant != "none":
            if policy.occ_comp == "none" and not rec and \
                    fp4_gemm.fused_backend_eligible(policy):
                # Clamp-only arm on the fused backend: the clamp runs
                # INSIDE the fused kernel's K-loop (no clamped copy of A
                # in HBM). The residual is never needed here; with obs on
                # we keep the composed clamp so record_clamp sees Delta.
                lo, hi = occ_mod.quantile_thresholds(
                    jax.lax.stop_gradient(a), policy.occ_alpha,
                    policy.occ_threshold)
                y = fp4_matmul(a, w, policy, clamp_bounds=(lo, hi))
                if b is not None:
                    y = y + b.astype(y.dtype)
                return y
            a_c, delta = occ_mod.clamp_and_residual(a, policy.occ_alpha,
                                                    policy.occ_threshold)
            if rec:
                obs.record_clamp(jax.lax.stop_gradient(a),
                                 jax.lax.stop_gradient(delta))
            y = fp4_matmul(a_c, w, policy)
            if policy.occ_comp == "dense":
                comp = jnp.matmul(delta.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                                  preferred_element_type=jnp.float32)
                y = y + comp.astype(y.dtype)
            elif policy.occ_comp == "channel":
                k = max(1, int(math.ceil(policy.occ_channel_frac * w.shape[0])))
                comp = occ_mod.channel_compensation(
                    delta.astype(jnp.bfloat16), w.astype(jnp.bfloat16), k)
                y = y + comp.astype(y.dtype)
            elif policy.occ_comp != "none":
                raise ValueError(policy.occ_comp)
        else:
            y = fp4_matmul(a, w, policy)

    if b is not None:
        y = y + b.astype(y.dtype)
    return y


class _NullCtx:
    """Stand-in for obs.site() when observability is off."""

    def __enter__(self):
        return False

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()
