"""FP4 GeMM: quantize both operands, multiply, rescale (paper Fig. 2).

The whole pipeline is built from differentiable pieces so JAX autodiff
composes the paper's backward exactly (derivation: App. C.2):

    sw   = stop_grad(6 / absmax(w, axis=0))          channel-wise
    w_q  = DGE(w * sw)                               hard quant fwd, f' bwd
    sa   = stop_grad(6 / absmax(a, axis=-1))         token-wise
    a_q  = STE(a * sa)
    y    = (a_q @ w_q) / (sa x sw)                   outer-product rescale

Autodiff then yields
    dW = (A_dq^T @ g) . f'(W_scaled)      == paper Eq. (22)
    dA = g @ W_dq^T                        (STE through activation quant)
with all scale factors cancelling exactly as in App. C.2.

GeMM backends:
  * "bf16_sim": grid values carried in bf16 (every E2M1 grid point is exact
    in bf16), f32 accumulation. The simulation reference -- same numerics
    the paper used on H100 FP8 cores.
  * "int8": TPU-native path. E2M1 grid x2 is integer, so the product of
    int8 codes equals 4x the FP4 product exactly; accumulate in int32 and
    fold /4 into the output rescale. On TPU v5e this hits the 394 TOPS int8
    MXU path (2x bf16), realizing the paper's FP4:FP8 = 2x throughput claim.
  * "pallas": the fused Pallas kernel (kernels/fp4_matmul.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs

from . import dge as dge_mod
from . import formats, quantize
from .policy import QuantPolicy

stop_grad = jax.lax.stop_gradient


def _quantize_weight(w: jnp.ndarray, policy: QuantPolicy):
    """Returns (w_q on grid, sw). w: (K, N); channel-wise => per-column."""
    fmt = formats.get_format(policy.fmt)
    sw = stop_grad(quantize.absmax_scale(w, policy.w_axis, fmt.max_value))
    w_scaled = w.astype(jnp.float32) * sw
    if policy.w_quant == "dge":
        w_q = dge_mod.dge_quantize(w_scaled, policy.dge_k, policy.dge_clip, policy.fmt)
    elif policy.w_quant == "ste":
        w_q = dge_mod.ste_quantize(w_scaled, policy.fmt)
    elif policy.w_quant == "none":
        # weight stays high precision ("W8" arm); identity scale semantics.
        return w.astype(jnp.float32) * sw, sw
    else:
        raise ValueError(policy.w_quant)
    if policy.obs_metrics and obs.active() is not None:
        obs.record_scale("weight", w, sw, policy.w_axis)
        obs.record_quant_error("weight", w, w_q, sw)
        if policy.w_quant == "dge":
            obs.record_dge(stop_grad(w_scaled), stop_grad(w_q),
                           dge_mod.dge_derivative(stop_grad(w_scaled),
                                                  policy.dge_k,
                                                  policy.dge_clip,
                                                  policy.fmt))
    return w_q, sw


def _quantize_act(a: jnp.ndarray, policy: QuantPolicy):
    """Returns (a_q on grid, sa). a: (..., K); token-wise => per-row."""
    fmt = formats.get_format(policy.fmt)
    sa = stop_grad(quantize.absmax_scale(a, policy.a_axis, fmt.max_value))
    a_scaled = a.astype(jnp.float32) * sa
    if policy.a_quant == "ste":
        a_q = dge_mod.ste_quantize(a_scaled, policy.fmt)
    elif policy.a_quant == "none":
        a_q = a_scaled  # high-precision activation ("A8" arm)
    else:
        raise ValueError(policy.a_quant)
    if policy.obs_metrics and obs.active() is not None and \
            policy.a_quant != "none":
        obs.record_scale("act", a, sa, policy.a_axis)
        obs.record_quant_error("act", a, stop_grad(a_q), sa)
    return a_q, sa


def _gemm_bf16(a_q, w_q):
    return jnp.matmul(a_q.astype(jnp.bfloat16), w_q.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


@jax.custom_vjp
def _int8_gemm_ste(a_q, w_q):
    """int8 exact FP4 product: (2a)(2w)/4. Forward-only int8; backward falls
    back to bf16 grid-value GeMMs (the backward pass is high precision in the
    paper's recipe)."""
    a8 = jnp.round(a_q * formats.E2M1_INT8_SCALE).astype(jnp.int8)
    w8 = jnp.round(w_q * formats.E2M1_INT8_SCALE).astype(jnp.int8)
    acc = jnp.matmul(a8, w8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) / (formats.E2M1_INT8_SCALE ** 2)


def _int8_gemm_fwd(a_q, w_q):
    return _int8_gemm_ste(a_q, w_q), (a_q, w_q)


def _int8_gemm_bwd(res, g):
    a_q, w_q = res
    ga = jnp.matmul(g, w_q.astype(jnp.bfloat16).T, preferred_element_type=jnp.float32)
    gw = jnp.matmul(a_q.astype(jnp.bfloat16).reshape(-1, a_q.shape[-1]).T,
                    g.reshape(-1, g.shape[-1]), preferred_element_type=jnp.float32)
    return ga.astype(a_q.dtype), gw.astype(w_q.dtype)


_int8_gemm_ste.defvjp(_int8_gemm_fwd, _int8_gemm_bwd)


def fp4_matmul(a: jnp.ndarray, w: jnp.ndarray, policy: QuantPolicy) -> jnp.ndarray:
    """y = FP4(a) @ FP4(w) with vector-wise rescale. a: (..., K), w: (K, N).

    Output dtype = policy.compute_dtype. Fully differentiable; the DGE/STE
    estimators live inside the quantizers.
    """
    if not policy.enabled:
        return jnp.matmul(a, w, preferred_element_type=jnp.float32).astype(
            policy.compute_dtype)

    a_q, sa = _quantize_act(a, policy)
    w_q, sw = _quantize_weight(w, policy)

    if policy.gemm_backend == "bf16_sim" or policy.a_quant == "none" or \
            policy.w_quant == "none":
        acc = _gemm_bf16(a_q, w_q)
    elif policy.gemm_backend == "int8":
        acc = _int8_gemm_ste(a_q, w_q)
    elif policy.gemm_backend == "pallas":
        from repro.kernels import ops as kernel_ops  # lazy: optional dep
        acc = kernel_ops.fp4_matmul_pallas(a_q, w_q)
    else:
        raise ValueError(policy.gemm_backend)

    # Outer-product rescale (Fig. 2): sa broadcasts over rows, sw over cols.
    inv = 1.0 / sa if policy.a_axis is not None else jnp.asarray(1.0 / sa)
    acc = acc * inv
    acc = acc / sw
    return acc.astype(policy.compute_dtype)
