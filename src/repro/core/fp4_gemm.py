"""FP4 GeMM: quantize both operands, multiply, rescale (paper Fig. 2).

The whole pipeline is built from differentiable pieces so JAX autodiff
composes the paper's backward exactly (derivation: App. C.2):

    sw   = stop_grad(6 / absmax(w, axis=0))          channel-wise
    w_q  = DGE(w * sw)                               hard quant fwd, f' bwd
    sa   = stop_grad(6 / absmax(a, axis=-1))         token-wise
    a_q  = STE(a * sa)
    y    = (a_q @ w_q) / (sa x sw)                   outer-product rescale

Autodiff then yields
    dW = (A_dq^T @ g) . f'(W_scaled)      == paper Eq. (22)
    dA = g @ W_dq^T                        (STE through activation quant)
with all scale factors cancelling exactly as in App. C.2.

GeMM backends:
  * "bf16_sim": grid values carried in bf16 (every E2M1 grid point is exact
    in bf16), f32 accumulation. The simulation reference -- same numerics
    the paper used on H100 FP8 cores.
  * "int8": TPU-native path. E2M1 grid x2 is integer, so the product of
    int8 codes equals 4x the FP4 product exactly; accumulate in int32 and
    fold /4 into the output rescale. On TPU v5e this hits the 394 TOPS int8
    MXU path (2x bf16), realizing the paper's FP4:FP8 = 2x throughput claim.
  * "pallas": the Pallas dequantizing-GeMM kernel (kernels/fp4_matmul.py);
    quantization still happens outside (the split path: quantize kernel ->
    HBM -> GeMM kernel).
  * "pallas_fused": the single-pass pipeline (kernels/fp4_fused.py) behind
    `jax.custom_vjp`: clamp + token-wise scaling + E2M1 quantization run
    inside the GEMM's K-loop (no A_q in HBM), the backward runs the fused
    dgrad (g @ W_dq^T) and DGE-masked wgrad (Eq. 22) Pallas kernels, and
    the wgrad RE-quantizes the activation in-kernel instead of saving A_q
    as a residual. Falls back to the composed path for the `w_quant="none"`
    / `a_quant="none"` arms and non-vector-wise granularities (DESIGN.md
    §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs

from . import dge as dge_mod
from . import formats, quantize
from .policy import QuantPolicy

stop_grad = jax.lax.stop_gradient


def _quantize_weight(w: jnp.ndarray, policy: QuantPolicy):
    """Returns (w_q on grid, sw). w: (K, N); channel-wise => per-column."""
    fmt = formats.get_format(policy.fmt)
    sw = stop_grad(quantize.absmax_scale(w, policy.w_axis, fmt.max_value))
    w_scaled = w.astype(jnp.float32) * sw
    if policy.w_quant == "dge":
        w_q = dge_mod.dge_quantize(w_scaled, policy.dge_k, policy.dge_clip, policy.fmt)
    elif policy.w_quant == "ste":
        w_q = dge_mod.ste_quantize(w_scaled, policy.fmt)
    elif policy.w_quant == "none":
        # weight stays high precision ("W8" arm); identity scale semantics.
        return w.astype(jnp.float32) * sw, sw
    else:
        raise ValueError(policy.w_quant)
    if policy.obs_metrics and obs.active() is not None:
        obs.record_scale("weight", w, sw, policy.w_axis)
        obs.record_quant_error("weight", w, w_q, sw)
        if policy.w_quant == "dge":
            obs.record_dge(stop_grad(w_scaled), stop_grad(w_q),
                           dge_mod.dge_derivative(stop_grad(w_scaled),
                                                  policy.dge_k,
                                                  policy.dge_clip,
                                                  policy.fmt))
    return w_q, sw


def _quantize_act(a: jnp.ndarray, policy: QuantPolicy):
    """Returns (a_q on grid, sa). a: (..., K); token-wise => per-row."""
    fmt = formats.get_format(policy.fmt)
    sa = stop_grad(quantize.absmax_scale(a, policy.a_axis, fmt.max_value))
    a_scaled = a.astype(jnp.float32) * sa
    if policy.a_quant == "ste":
        a_q = dge_mod.ste_quantize(a_scaled, policy.fmt)
    elif policy.a_quant == "none":
        a_q = a_scaled  # high-precision activation ("A8" arm)
    else:
        raise ValueError(policy.a_quant)
    if policy.obs_metrics and obs.active() is not None and \
            policy.a_quant != "none":
        obs.record_scale("act", a, sa, policy.a_axis)
        obs.record_quant_error("act", a, stop_grad(a_q), sa)
    return a_q, sa


def _gemm_bf16(a_q, w_q):
    return jnp.matmul(a_q.astype(jnp.bfloat16), w_q.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


@jax.custom_vjp
def _int8_gemm_ste(a_q, w_q):
    """int8 exact FP4 product: (2a)(2w)/4. Forward-only int8; backward falls
    back to bf16 grid-value GeMMs (the backward pass is high precision in the
    paper's recipe)."""
    a8 = jnp.round(a_q * formats.E2M1_INT8_SCALE).astype(jnp.int8)
    w8 = jnp.round(w_q * formats.E2M1_INT8_SCALE).astype(jnp.int8)
    acc = jnp.matmul(a8, w8, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) / (formats.E2M1_INT8_SCALE ** 2)


def _int8_gemm_fwd(a_q, w_q):
    return _int8_gemm_ste(a_q, w_q), (a_q, w_q)


def _int8_gemm_bwd(res, g):
    a_q, w_q = res
    ga = jnp.matmul(g, w_q.astype(jnp.bfloat16).T, preferred_element_type=jnp.float32)
    gw = jnp.matmul(a_q.astype(jnp.bfloat16).reshape(-1, a_q.shape[-1]).T,
                    g.reshape(-1, g.shape[-1]), preferred_element_type=jnp.float32)
    return ga.astype(a_q.dtype), gw.astype(w_q.dtype)


_int8_gemm_ste.defvjp(_int8_gemm_fwd, _int8_gemm_bwd)


# ---------------------------------------------------------------------------
# Fused single-pass backend (kernels/fp4_fused.py) behind a custom VJP.
#
# Derivation (matches the autodiff-composed path exactly, App. C.2):
#   y[m,n]  = (Q(a*sa) @ Q(w*sw))[m,n] / (sa[m]*sw[n])
#   dA      = g @ (W_q/sw)^T          -- sa cancels through the STE
#   dW      = ((A_q/sa)^T @ g) * f'(w*sw)  -- sw cancels through the DGE
# The clamp bounds (lo, hi) participate in the forward only; their
# cotangents are zero (OCC thresholds are stop_gradient'ed upstream) and
# dA is masked by the clamp indicator 1{lo <= a <= hi}.
# ---------------------------------------------------------------------------


def fused_backend_eligible(policy: QuantPolicy) -> bool:
    """True when `gemm_backend="pallas_fused"` actually takes the fused
    kernel path; the high-precision arms and non-vector-wise granularities
    fall back to the composed simulation (DESIGN.md §12)."""
    return (policy.gemm_backend == "pallas_fused"
            and policy.a_quant == "ste"
            and policy.w_quant in ("dge", "ste")
            and policy.a_axis == -1
            and policy.w_axis == 0)


def _fused_fwd_impl(a2d, w, lohi, policy: QuantPolicy):
    from repro.kernels import ops as kernel_ops  # lazy: optional dep
    fmt = formats.get_format(policy.fmt)
    sw = stop_grad(quantize.absmax_scale(w, 0, fmt.max_value))
    w_q = quantize.lut_round(w.astype(jnp.float32) * sw, policy.fmt)
    sa = kernel_ops.fused_row_scale(a2d, lohi, fmt=policy.fmt)
    y = kernel_ops.fp4_matmul_fused(a2d, w_q, sa, sw, lohi, fmt=policy.fmt)
    return y, sa, w_q, sw


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_gemm(a2d, w, lohi, policy: QuantPolicy):
    y, _, _, _ = _fused_fwd_impl(a2d, w, lohi, policy)
    return y


def _fused_gemm_fwd(a2d, w, lohi, policy):
    y, sa, w_q, sw = _fused_fwd_impl(a2d, w, lohi, policy)
    return y, (a2d, w, lohi, sa, w_q, sw)


def _fused_gemm_bwd(policy, res, g):
    from repro.kernels import ops as kernel_ops
    a2d, w, lohi, sa, w_q, sw = res
    g32 = g.astype(jnp.float32)
    da = kernel_ops.fp4_dgrad_fused(g32, w_q, sw)
    # Clamp indicator (identity for the +/-inf no-clamp bounds). Matches
    # jnp.clip's VJP except exactly ON a finite bound, where clip's
    # max/min subgradient halves the cotangent (measure-zero; §12).
    af = a2d.astype(jnp.float32)
    da = da * ((af >= lohi[0, 0]) & (af <= lohi[0, 1])).astype(jnp.float32)
    if policy.w_quant == "dge":
        mask = dge_mod.dge_derivative(w.astype(jnp.float32) * sw,
                                      policy.dge_k, policy.dge_clip,
                                      policy.fmt)
    else:  # "ste"
        mask = jnp.ones(w.shape, jnp.float32)
    dw = kernel_ops.fp4_wgrad_fused(a2d, sa, g32, mask, lohi,
                                    fmt=policy.fmt)
    return (da.astype(a2d.dtype), dw.astype(w.dtype),
            jnp.zeros_like(lohi))


_fused_gemm.defvjp(_fused_gemm_fwd, _fused_gemm_bwd)


def _fused_path(a, w, policy: QuantPolicy, clamp_bounds) -> jnp.ndarray:
    """Dispatch a (..., K) activation through the fused backend."""
    orig_shape = None
    if a.ndim > 2:
        orig_shape = a.shape
        a = a.reshape(-1, a.shape[-1])
    if clamp_bounds is None:
        lohi = jnp.asarray([[-jnp.inf, jnp.inf]], jnp.float32)
    else:
        lohi = jnp.stack([jnp.asarray(clamp_bounds[0], jnp.float32),
                          jnp.asarray(clamp_bounds[1], jnp.float32)]
                         ).reshape(1, 2)
    y = _fused_gemm(a, w, stop_grad(lohi), policy)
    if policy.obs_metrics and obs.active() is not None:
        # Same vocabulary as the composed path, recomputed with jnp from
        # the raw operands (obs-on runs are simulation/debug mode; the
        # fused kernel itself stays stats-free).
        fmt = formats.get_format(policy.fmt)
        a_c = stop_grad(jnp.clip(a.astype(jnp.float32), lohi[0, 0],
                                 lohi[0, 1]))
        sa = quantize.absmax_scale(a_c, -1, fmt.max_value)
        a_q = quantize.lut_round(a_c * sa, policy.fmt)
        obs.record_scale("act", a_c, sa, -1)
        obs.record_quant_error("act", a_c, a_q, sa)
        sw = stop_grad(quantize.absmax_scale(w, 0, fmt.max_value))
        w_scaled = stop_grad(w.astype(jnp.float32) * sw)
        w_q = quantize.lut_round(w_scaled, policy.fmt)
        obs.record_scale("weight", w, sw, 0)
        obs.record_quant_error("weight", w, w_q, sw)
        if policy.w_quant == "dge":
            obs.record_dge(w_scaled, w_q,
                           dge_mod.dge_derivative(w_scaled, policy.dge_k,
                                                  policy.dge_clip,
                                                  policy.fmt))
    if orig_shape is not None:
        y = y.reshape(*orig_shape[:-1], y.shape[-1])
    return y.astype(policy.compute_dtype)


def fp4_matmul(a: jnp.ndarray, w: jnp.ndarray, policy: QuantPolicy, *,
               clamp_bounds=None) -> jnp.ndarray:
    """y = FP4(a) @ FP4(w) with vector-wise rescale. a: (..., K), w: (K, N).

    Output dtype = policy.compute_dtype. Fully differentiable; the DGE/STE
    estimators live inside the quantizers (composed path) or inside the
    custom VJP (`pallas_fused` backend).

    `clamp_bounds=(lo, hi)` folds the OCC clamp into the fused kernel when
    the fused backend is eligible; on any fallback path the clamp is
    applied with jnp.clip before quantization, so semantics never depend
    on the backend.
    """
    if not policy.enabled:
        return jnp.matmul(a, w, preferred_element_type=jnp.float32).astype(
            policy.compute_dtype)

    if fused_backend_eligible(policy):
        return _fused_path(a, w, policy, clamp_bounds)
    if clamp_bounds is not None:
        a = jnp.clip(a, jnp.asarray(clamp_bounds[0], a.dtype),
                     jnp.asarray(clamp_bounds[1], a.dtype))

    a_q, sa = _quantize_act(a, policy)
    w_q, sw = _quantize_weight(w, policy)

    if policy.gemm_backend in ("bf16_sim", "pallas_fused") or \
            policy.a_quant == "none" or policy.w_quant == "none":
        # "pallas_fused" reaching this line means the policy was not
        # fused-eligible (high-precision arm / tensor-wise granularity):
        # simulate with the composed bf16 path.
        acc = _gemm_bf16(a_q, w_q)
    elif policy.gemm_backend == "int8":
        acc = _int8_gemm_ste(a_q, w_q)
    elif policy.gemm_backend == "pallas":
        from repro.kernels import ops as kernel_ops  # lazy: optional dep
        acc = kernel_ops.fp4_matmul_pallas(a_q, w_q)
    else:
        raise ValueError(policy.gemm_backend)

    # Outer-product rescale (Fig. 2): sa broadcasts over rows, sw over
    # cols; with tensor-wise granularity both are scalars. One division
    # chain for every granularity -- the old code special-cased
    # `a_axis is None` with a reciprocal-then-multiply, whose extra
    # rounding made the scalar-scale arm drift from the vector-wise path
    # (and from kernels/ref.py, which divides).
    acc = acc / sa / sw
    return acc.astype(policy.compute_dtype)
