"""Absmax vector-wise FP4/FP8 quantization (pure-jnp reference semantics).

Quantization follows the paper's Eq. (1): x_q = Q(x * gamma) with
gamma = MAX_fmt / absmax(x). `Q` is round-to-nearest on the format grid,
implemented with `searchsorted` over the LUT boundaries (identical to the
paper's CUDA threshold chain, Appendix A).

Granularity (paper §4.1/§4.3):
  * activations: token-wise  -> axis=-1 reduction (one scale per row)
  * weights:     channel-wise-> axis=0  reduction (one scale per out column)
  * tensor-wise kept for the granularity ablation (Fig. 6d).

All functions return the *scaled* quantized tensor plus the scale so callers
can fold 1/(sa*sw) into the GeMM epilogue (the scales never enter the GeMM,
matching Fig. 2).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import formats
from .formats import E2M1, FP4Format

_EPS = 1e-12


def lut_round(x: jnp.ndarray, fmt: FP4Format | str = E2M1) -> jnp.ndarray:
    """Round-to-nearest on the format grid via boundary LUT (paper App. A)."""
    values, bounds = formats.grid(fmt)
    idx = jnp.searchsorted(bounds, x.astype(jnp.float32), side="right")
    return values[idx].astype(x.dtype)


def absmax_scale(x: jnp.ndarray, axis: int | Sequence[int] | None,
                 max_value: float) -> jnp.ndarray:
    """gamma = MAX / absmax(x) along `axis` (None => tensor-wise).

    All-zero slices get scale 1.0 (they quantize to 0 regardless); non-zero
    slices map their absmax exactly onto the format max, however small.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=axis is not None)
    # slices with absmax below ~1e-30 would overflow the f32 scale
    # (6/1.2e-38 = inf); they carry no representable signal at 4 bits and
    # quantize to zero via scale 1.
    return max_value / jnp.where(amax > 1e-30, amax, max_value)


def quantize(x: jnp.ndarray, axis: int | Sequence[int] | None = None,
             fmt: FP4Format | str = E2M1):
    """Quantize to FP4. Returns (x_q_scaled, scale).

    x_q_scaled lies on the format grid (range [-MAX, MAX]); the dequantized
    tensor is x_q_scaled / scale. `axis` selects granularity: -1 for
    token-wise activations, 0 for channel-wise weights, None tensor-wise.
    """
    fmt = formats.get_format(fmt)
    scale = absmax_scale(x, axis, fmt.max_value)
    x_scaled = x.astype(jnp.float32) * scale
    return lut_round(x_scaled, fmt), scale


def dequantize(x_q: jnp.ndarray, scale: jnp.ndarray, dtype=None) -> jnp.ndarray:
    """Undo Eq. (1)'s scaling: x ~= x_q_scaled / gamma (broadcast over axis)."""
    out = x_q.astype(jnp.float32) / scale
    return out.astype(dtype) if dtype is not None else out


def fake_quant(x: jnp.ndarray, axis: int | Sequence[int] | None = None,
               fmt: FP4Format | str = E2M1) -> jnp.ndarray:
    """quantize->dequantize in the input dtype (simulation convenience)."""
    q, s = quantize(x, axis, fmt)
    return dequantize(q, s, dtype=x.dtype)


# ---------------------------------------------------------------------------
# FP8 helpers (optimizer moments + gradient communication, after FP8-LM).
# Uses native jnp.float8_e4m3fn storage with a per-tensor power-of-2-free
# absmax scale.
# ---------------------------------------------------------------------------

def quantize_fp8(x: jnp.ndarray, e4m3: bool = True):
    """Quantize to native fp8 storage. Returns (fp8_tensor, f32 scale)."""
    maxv = formats.FP8_E4M3_MAX if e4m3 else formats.FP8_E5M2_MAX
    dtype = jnp.float8_e4m3fn if e4m3 else jnp.float8_e5m2
    scale = absmax_scale(x, None, maxv)
    return (x.astype(jnp.float32) * scale).astype(dtype), scale


def dequantize_fp8(x8: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    """Inverse of `quantize_fp8`: fp8 storage + scale back to `dtype`."""
    return (x8.astype(jnp.float32) / scale).astype(dtype)
