"""Differentiable Gradient Estimator (paper §3.1, Appendix C).

Forward: hard LUT quantization (identical bits to `quantize.lut_round`).
Backward: the weight gradient is multiplied element-wise by f'(x), the
derivative of the power-law soft-step that approximates the quantizer
inside each quantization interval (Eq. 8 generalized to E2M1's variable
interval widths):

    t      = (x - lo) / delta            position inside interval [lo, hi]
    f'(x)  = (1/k) * |2t - 1| ** (1/k - 1)

clipped at `clip` (= 3.0, App. C.3 shows clipping is equivalent to the
eps-smoothed derivative). Outside the representable range the quantizer
saturates, so f' = 0 there (absmax scaling guarantees |x| <= MAX for the
tensor the estimator is applied to, so this only matters for adversarial
inputs).

Appendix C.2 proves the channel-wise scale and its inverse cancel through
the chain rule, so DGE applies to the *scaled* weight tensor directly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import formats, quantize
from .formats import E2M1, FP4Format

DEFAULT_K = 5.0
DEFAULT_CLIP = 3.0


def dge_derivative(x: jnp.ndarray, k: float = DEFAULT_K,
                   clip: float = DEFAULT_CLIP,
                   fmt: FP4Format | str = E2M1) -> jnp.ndarray:
    """f'(x) of Eq. (8) over the full E2M1 range, clipped (App. C.3)."""
    fmt = formats.get_format(fmt)
    los, deltas = formats.intervals(fmt)
    xf = x.astype(jnp.float32)
    # Interval index: values[i] <= x < values[i+1]. searchsorted over the
    # interval lower edges gives i+1 for interior points.
    idx = jnp.clip(jnp.searchsorted(los, xf, side="right") - 1, 0, los.shape[0] - 1)
    lo = los[idx]
    delta = deltas[idx]
    t = (xf - lo) / delta
    inner = jnp.abs(2.0 * t - 1.0)
    # |2t-1|^(1/k - 1) diverges at t=1/2; clip per App. C.3.
    deriv = (1.0 / k) * jnp.power(jnp.maximum(inner, _pow_floor(k, clip)), 1.0 / k - 1.0)
    deriv = jnp.minimum(deriv, clip)
    # Saturation outside the representable range.
    in_range = jnp.abs(xf) <= fmt.max_value
    return jnp.where(in_range, deriv, 0.0).astype(x.dtype)


def _pow_floor(k: float, clip: float) -> float:
    """Smallest |2t-1| whose derivative stays <= clip: solves
    (1/k)*m^(1/k-1) = clip  =>  m = (k*clip)^(k/(1-k)). Flooring the power
    argument (instead of only min-ing the result) keeps the computation
    finite in f32 even exactly at t=1/2."""
    return float((k * clip) ** (k / (1.0 - k)))


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def dge_quantize(x_scaled: jnp.ndarray, k: float = DEFAULT_K,
                 clip: float = DEFAULT_CLIP, fmt_name: str = "e2m1") -> jnp.ndarray:
    """Hard LUT quantization forward; DGE-corrected gradient backward.

    Applies to the *scaled* tensor (|x| <= MAX). Non-diff args are static so
    the estimator stays a fixed function (paper §5: no learnable quantizer).
    """
    return quantize.lut_round(x_scaled, fmt_name)


def _dge_fwd(x_scaled, k, clip, fmt_name):
    return dge_quantize(x_scaled, k, clip, fmt_name), x_scaled


def _dge_bwd(k, clip, fmt_name, x_scaled, g):
    return (g * dge_derivative(x_scaled, k, clip, fmt_name).astype(g.dtype),)


dge_quantize.defvjp(_dge_fwd, _dge_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quantize(x_scaled: jnp.ndarray, fmt_name: str = "e2m1") -> jnp.ndarray:
    """Straight-through estimator baseline: f'(x) == 1 (paper Fig. 3)."""
    return quantize.lut_round(x_scaled, fmt_name)


def _ste_fwd(x_scaled, fmt_name):
    return ste_quantize(x_scaled, fmt_name), None


def _ste_bwd(fmt_name, _, g):
    return (g,)


ste_quantize.defvjp(_ste_fwd, _ste_bwd)
