"""Low-precision float formats: FP4 (E2M1/E1M2/E3M0) and FP8 helpers.

FP4 value grids follow the paper's Appendix A (Table 4). E2M1 is the
production format (balanced dynamic range vs precision); the alternates are
kept for ablations. Round-to-nearest boundaries reproduce the paper's CUDA
LUT kernel exactly (ties resolved identically to the published thresholds:
e.g. values in [-0.25, 0.25) -> 0, [2.5, 3.5) -> 3).

TPU adaptation: every E2M1 grid value x2 is a small integer, so the grid is
exactly representable in int8 -- `to_int8_codes` / `from_int8_codes` expose
that mapping for the int8-MXU GeMM path, and `pack_e2m1` / `unpack_e2m1`
pack two 4-bit code indices per byte for 4-bit HBM storage.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FP4Format:
    """A 16-entry 4-bit float format described by its non-negative grid."""

    name: str
    # Non-negative representable values, ascending, starting at 0.
    positive_values: tuple[float, ...]

    @property
    def max_value(self) -> float:
        """Largest representable magnitude (MAX_fmt in the paper's Eq. 2)."""
        return self.positive_values[-1]

    @property
    def values(self) -> np.ndarray:
        """All representable values, ascending (15 distinct: +/-0 collapse)."""
        pos = np.asarray(self.positive_values, dtype=np.float64)
        return np.concatenate([-pos[:0:-1], pos])

    @property
    def boundaries(self) -> np.ndarray:
        """Round-to-nearest decision boundaries (midpoints), len = len(values)-1."""
        v = self.values
        return (v[:-1] + v[1:]) / 2.0


# Paper Table 4 formats.
E2M1 = FP4Format("e2m1", (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0))
E1M2 = FP4Format("e1m2", (0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5))
E3M0 = FP4Format("e3m0", (0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0))

FORMATS: dict[str, FP4Format] = {f.name: f for f in (E2M1, E1M2, E3M0)}

# FP8 dynamic ranges (OCP spec): E4M3 max 448, E5M2 max 57344.
FP8_E4M3_MAX = 448.0
FP8_E5M2_MAX = 57344.0


@lru_cache(maxsize=None)
def _grid_arrays(fmt_name: str):
    # Cached as NUMPY (never jnp): jnp constants created inside a trace are
    # tracers and must not be cached across traces.
    fmt = FORMATS[fmt_name]
    values = np.asarray(fmt.values, dtype=np.float32)
    bounds = np.asarray(fmt.boundaries, dtype=np.float32)
    return values, bounds


def grid(fmt: FP4Format | str):
    """(values, boundaries) as jnp f32 arrays for a format."""
    name = fmt if isinstance(fmt, str) else fmt.name
    values, bounds = _grid_arrays(name)
    return jnp.asarray(values), jnp.asarray(bounds)


def get_format(fmt: FP4Format | str) -> FP4Format:
    """Resolve a format name ("e2m1"/"e1m2"/"e3m0") or pass one through."""
    return fmt if isinstance(fmt, FP4Format) else FORMATS[fmt]


# ---------------------------------------------------------------------------
# Interval metadata for DGE: for each grid value index i (< len-1), the
# interval is [values[i], values[i+1]] with width delta[i]. DGE evaluates the
# soft-step derivative relative to the interval containing x.
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _interval_arrays(fmt_name: str):
    fmt = FORMATS[fmt_name]
    v = fmt.values
    los = np.asarray(v[:-1], dtype=np.float32)
    deltas = np.asarray(v[1:] - v[:-1], dtype=np.float32)
    return los, deltas


def intervals(fmt: FP4Format | str):
    """(interval_lows, interval_widths) for DGE derivative evaluation."""
    name = fmt if isinstance(fmt, str) else fmt.name
    los, deltas = _interval_arrays(name)
    return jnp.asarray(los), jnp.asarray(deltas)


# ---------------------------------------------------------------------------
# int8 exactness (TPU MXU path): E2M1 values x2 are integers.
# ---------------------------------------------------------------------------

E2M1_INT8_SCALE = 2  # int8_code = value * 2, exactly.


def to_int8_codes(x_on_grid: jnp.ndarray) -> jnp.ndarray:
    """Map values on the E2M1 grid to exact int8 (value*2). Input must already
    lie on the grid; this is a dtype/layout change, not a rounding step."""
    return jnp.round(x_on_grid * E2M1_INT8_SCALE).astype(jnp.int8)


def from_int8_codes(codes: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of `to_int8_codes`: exact int8 codes back to grid values."""
    return codes.astype(dtype) / E2M1_INT8_SCALE


# ---------------------------------------------------------------------------
# 4-bit packing: 2 grid indices per uint8 byte (HBM storage path).
# Index layout: value index in [0, 15) over the ascending 15-value grid;
# index 15 unused (E2M1 has +/-0 collapsed).
# ---------------------------------------------------------------------------

def values_to_indices(x_on_grid: jnp.ndarray, fmt: FP4Format | str = E2M1) -> jnp.ndarray:
    """On-grid values -> 4-bit grid indices in [0, 15) (storage codes)."""
    values, bounds = grid(fmt)
    return jnp.searchsorted(bounds, x_on_grid, side="right").astype(jnp.uint8)


def indices_to_values(idx: jnp.ndarray, fmt: FP4Format | str = E2M1,
                      dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of `values_to_indices`: grid indices back to float values."""
    values, _ = grid(fmt)
    return values.astype(dtype)[idx]


def pack_e2m1(idx: jnp.ndarray) -> jnp.ndarray:
    """Pack an even-length last dim of 4-bit indices into uint8 pairs."""
    if idx.shape[-1] % 2:
        raise ValueError("last dim must be even to pack 2 codes/byte")
    lo = idx[..., 0::2]
    hi = idx[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_e2m1(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of `pack_e2m1`: uint8 pairs back to 4-bit index arrays."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
