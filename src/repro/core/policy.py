"""QuantPolicy: the single knob surface for the FP4 training recipe.

A policy is a frozen (hashable) dataclass so it can be closed over by jitted
functions as a static argument. Preset policies reproduce the paper's
experimental arms (Fig. 6): BF16 baseline, the full FP4 recipe
(W4A4 + DGE + OCC), direct-cast W4A4, weight-only W4A8, activation-only
W8A4, and the tensor-wise granularity ablation.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
    "float16": jnp.float16,
}


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Every knob of the FP4 training recipe in one frozen dataclass.

    Field groups mirror the paper: weight quantization via DGE (Eq. 22's
    soft-step derivative with strength k and clip delta), activation
    quantization with OCC (quantile alpha clamp + residual compensation,
    §3.2), scale granularity (Eq. 2's absmax scaling, per-channel /
    per-token / tensor-wise), and the GeMM execution backend. Hashable so
    jitted functions close over it as a static argument; presets in
    `PRESETS` reproduce the paper's experimental arms.
    """

    enabled: bool = True
    fmt: str = "e2m1"

    # --- weights (paper §3.1) ---
    w_quant: str = "dge"            # "dge" | "ste" | "none"
    dge_k: float = 5.0
    dge_clip: float = 3.0
    w_axis: int | None = 0          # channel-wise (out-channel); None = tensor-wise

    # --- activations (paper §3.2) ---
    a_quant: str = "ste"            # "ste" | "none"
    a_axis: int | None = -1         # token-wise; None = tensor-wise
    occ: bool = True
    occ_alpha: float = 0.99
    occ_threshold: str = "sample"   # "exact" | "sample"
    occ_comp: str = "dense"         # "dense" | "channel" | "none"
    occ_channel_frac: float = 0.02  # top-k channel fraction for "channel"

    # --- GeMM execution ---
    # "bf16_sim" | "int8" | "pallas" (split quantize->GeMM kernels) |
    # "pallas_fused" (single-pass clamp+quant+GeMM+rescale kernel with a
    # custom-VJP fused backward; falls back to bf16_sim for the
    # high-precision and tensor-wise arms -- DESIGN.md §12)
    gemm_backend: str = "bf16_sim"
    compute: str = "bfloat16"       # non-GeMM compute dtype

    # --- scope ---
    quantize_head: bool = False     # LM head stays high-precision by default

    # --- observability (repro.obs; DESIGN.md §11) ---
    # When True, the FP4 path records per-site quant-health metrics into
    # the active obs collector and model.loss returns them under
    # metrics["obs"]. Off by default: zero traced ops added.
    obs_metrics: bool = False

    @property
    def compute_dtype(self):
        """The jnp dtype of non-GeMM compute (norms, softmax, residual)."""
        return _DTYPES[self.compute]

    def replace(self, **kw) -> "QuantPolicy":
        """A copy with the given fields replaced (dataclasses.replace)."""
        return dataclasses.replace(self, **kw)

    def fallback(self) -> "QuantPolicy":
        """The bf16 fallback arm the collapse sentinel flips to: FP4
        disabled, everything else (compute dtype, head scope) unchanged.
        Obs stays on so the health log shows the post-fallback regime."""
        return self.replace(enabled=False)


# --- preset experimental arms (paper Fig. 6) -------------------------------

BF16 = QuantPolicy(enabled=False)
FP4_PAPER = QuantPolicy()  # W4A4 + DGE + OCC, k=5, alpha=0.99, vector-wise
W4A4_DIRECT = QuantPolicy(w_quant="ste", occ=False)          # direct cast
W4A8 = QuantPolicy(a_quant="none", occ=False)                # weight-only 4b
W4A8_STE = QuantPolicy(w_quant="ste", a_quant="none", occ=False)
W8A4 = QuantPolicy(w_quant="none", occ=True)                 # act-only 4b
W8A4_DIRECT = QuantPolicy(w_quant="none", occ=False)
TENSOR_WISE = QuantPolicy(w_axis=None, a_axis=None)          # Fig. 6d arm

PRESETS: dict[str, QuantPolicy] = {
    "bf16": BF16,
    "fp4": FP4_PAPER,
    "fp4_obs": FP4_PAPER.replace(obs_metrics=True),  # instrumented arm
    "fp4_int8": FP4_PAPER.replace(gemm_backend="int8"),
    "fp4_pallas": FP4_PAPER.replace(gemm_backend="pallas"),
    "fp4_fused": FP4_PAPER.replace(gemm_backend="pallas_fused"),
    "fp4_fused_obs": FP4_PAPER.replace(gemm_backend="pallas_fused",
                                       obs_metrics=True),
    # beyond-paper TPU variants (§Perf hillclimb arms):
    "fp4_channel": FP4_PAPER.replace(occ_comp="channel"),
    "fp4_nocomp": FP4_PAPER.replace(occ_comp="none"),
    "fp4_channel_int8": FP4_PAPER.replace(occ_comp="channel",
                                          gemm_backend="int8"),
    "w4a4_direct": W4A4_DIRECT,
    "w4a8": W4A8,
    "w4a8_ste": W4A8_STE,
    "w8a4": W8A4,
    "w8a4_direct": W8A4_DIRECT,
    "tensor_wise": TENSOR_WISE,
}


def get_policy(name: str) -> QuantPolicy:
    """Look up a preset policy by name (see `PRESETS`; KeyError if unknown)."""
    if name not in PRESETS:
        raise KeyError(f"unknown policy {name!r}; have {sorted(PRESETS)}")
    return PRESETS[name]
