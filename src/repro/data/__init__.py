"""repro.data -- input pipeline (v2: streaming shards, DESIGN.md §14).

Two data paths feed the trainer:

  * synthetic  -- `SyntheticLM` / `SyntheticStream`: deterministic
    Zipf+Markov token process, no files on disk (CI, unit tests, quick
    smoke trains).
  * shards     -- `ShardWriter`/`ShardReader` (memory-mapped token
    shards + JSON manifest), `PackedStream` (checkpointable best-fit
    packing with segment-ID masks), `DevicePrefetcher` (async
    host->device double buffering).

Both stream flavors expose next_batch()/state_dict()/load_state_dict(),
so `train/trainer.py` checkpoints and resumes either one bit-exactly.
See docs/data_format.md for the on-disk layout and resume guarantees.
"""
from .packing import PackedBatch, assemble, best_fit, split_spans
from .prefetch import DevicePrefetcher
from .shards import ShardReader, ShardWriter, token_dtype
from .stream import PackedStream, SyntheticStream
from .synthetic import (DataConfig, SyntheticLM, make_batch_fn,
                        synthetic_documents, write_synthetic_shards)

__all__ = [
    "PackedBatch", "assemble", "best_fit", "split_spans",
    "DevicePrefetcher", "ShardReader", "ShardWriter", "token_dtype",
    "PackedStream", "SyntheticStream",
    "DataConfig", "SyntheticLM", "make_batch_fn",
    "synthetic_documents", "write_synthetic_shards",
]
