"""Tokenized shard format: memory-mappable fixed-dtype records + manifest.

On-disk layout (documented in docs/data_format.md; DESIGN.md §14):

    <root>/manifest.json         format version, token dtype, vocab size,
                                 per-shard doc/token counts
    <root>/shard_00000.bin       raw little-endian token ids, documents
                                 concatenated back to back
    <root>/shard_00000.idx       raw int64 document offsets, n_docs+1
                                 entries (offsets[i]..offsets[i+1] is doc i)

Both the ``.bin`` and ``.idx`` files are flat arrays with no header, so a
reader memory-maps them (`np.memmap`) and never materializes a shard in
RAM. Token dtype is ``uint16`` when ``vocab_size <= 65536`` else
``uint32``; document boundaries come only from the index file.

Writers are atomic at the manifest level: shards are written first and
``manifest.json`` last (fsynced tmp + atomic rename), so a directory
with a manifest is always complete; a kill mid-write leaves a
manifest-less directory that readers refuse.  Readers validate what they
open (DESIGN.md §15): a corrupt manifest raises a clean ``ValueError``,
and ``.bin``/``.idx`` files whose sizes disagree with the manifest --
the on-disk shape of truncation or a mixed-up directory -- are rejected
at map time instead of silently serving short or garbage documents.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.chaos.hooks import chaos_point

FORMAT_NAME = "repro-shards-v1"
_IDX_DTYPE = np.int64


def token_dtype(vocab_size: int) -> np.dtype:
    """Smallest fixed-width unsigned dtype that holds `vocab_size` ids."""
    return np.dtype(np.uint16 if vocab_size <= 1 << 16 else np.uint32)


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    """One shard's manifest entry (file names + record counts)."""

    file: str
    idx: str
    n_docs: int
    n_tokens: int


class ShardWriter:
    """Streaming shard writer: feed documents, get a manifest.

    Documents accumulate into the current shard until `shard_tokens` is
    reached, then the shard rolls over. `finalize()` writes the manifest
    (the commit point) and returns its path.
    """

    def __init__(self, root: str, vocab_size: int,
                 shard_tokens: int = 1 << 24):
        self.root = root
        self.vocab_size = vocab_size
        self.shard_tokens = shard_tokens
        self.dtype = token_dtype(vocab_size)
        self.shards: list[ShardInfo] = []
        os.makedirs(root, exist_ok=True)
        self._bin = None
        self._offsets: list[int] = []
        self._cur_tokens = 0

    def _open_shard(self):
        i = len(self.shards)
        self._bin_name = f"shard_{i:05d}.bin"
        self._idx_name = f"shard_{i:05d}.idx"
        self._bin = open(os.path.join(self.root, self._bin_name), "wb")
        self._offsets = [0]
        self._cur_tokens = 0

    def _close_shard(self):
        if self._bin is None:
            return
        self._bin.close()
        chaos_point("shard.pre_idx", shard=self._bin_name)
        np.asarray(self._offsets, _IDX_DTYPE).tofile(
            os.path.join(self.root, self._idx_name))
        self.shards.append(ShardInfo(self._bin_name, self._idx_name,
                                     len(self._offsets) - 1,
                                     self._cur_tokens))
        self._bin = None

    def add_document(self, tokens: np.ndarray) -> None:
        """Append one document (1-D array of token ids) to the corpus."""
        tokens = np.asarray(tokens)
        if tokens.ndim != 1 or tokens.size == 0:
            raise ValueError(f"document must be 1-D non-empty, "
                             f"got shape {tokens.shape}")
        if tokens.max() >= self.vocab_size or tokens.min() < 0:
            raise ValueError("token id out of range for vocab_size="
                             f"{self.vocab_size}")
        if self._bin is None:
            self._open_shard()
        self._bin.write(tokens.astype(self.dtype).tobytes())
        self._cur_tokens += tokens.size
        self._offsets.append(self._cur_tokens)
        if self._cur_tokens >= self.shard_tokens:
            self._close_shard()

    def finalize(self, meta: dict | None = None) -> str:
        """Close the open shard and write `manifest.json` (commit point)."""
        self._close_shard()
        manifest = {
            "format": FORMAT_NAME,
            "dtype": self.dtype.name,
            "vocab_size": self.vocab_size,
            "total_docs": sum(s.n_docs for s in self.shards),
            "total_tokens": sum(s.n_tokens for s in self.shards),
            "shards": [dataclasses.asdict(s) for s in self.shards],
            "meta": meta or {},
        }
        path = os.path.join(self.root, "manifest.json")
        chaos_point("shard.pre_manifest", path=path)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path


class ShardReader:
    """Memory-mapped random access to a shard directory.

    Documents are addressed by a *global* doc id in [0, total_docs);
    `doc(gid)` returns a zero-copy memmap slice. Per-shard maps are opened
    lazily and kept, so sequential scans touch each file once.
    """

    def __init__(self, manifest_path: str):
        if os.path.isdir(manifest_path):
            manifest_path = os.path.join(manifest_path, "manifest.json")
        with open(manifest_path) as f:
            try:
                self.manifest = json.load(f)
            except ValueError as e:
                raise ValueError(f"corrupt shard manifest "
                                 f"{manifest_path}: {e}") from e
        if not isinstance(self.manifest, dict):
            raise ValueError(f"corrupt shard manifest {manifest_path}: "
                             "top level is not an object")
        if self.manifest.get("format") != FORMAT_NAME:
            raise ValueError(
                f"unsupported shard format {self.manifest.get('format')!r}"
                f" (expected {FORMAT_NAME})")
        missing = {"dtype", "vocab_size", "shards",
                   "total_tokens"} - self.manifest.keys()
        if missing:
            raise ValueError(f"corrupt shard manifest {manifest_path}: "
                             f"missing keys {sorted(missing)}")
        self.root = os.path.dirname(os.path.abspath(manifest_path))
        self.dtype = np.dtype(self.manifest["dtype"])
        self.vocab_size = int(self.manifest["vocab_size"])
        self.shards = self.manifest["shards"]
        counts = [s["n_docs"] for s in self.shards]
        self._doc_base = np.concatenate([[0], np.cumsum(counts)])
        self.total_docs = int(self._doc_base[-1])
        self.total_tokens = int(self.manifest["total_tokens"])
        self._maps: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    def _shard_maps(self, si: int):
        if si not in self._maps:
            s = self.shards[si]
            bin_path = os.path.join(self.root, s["file"])
            idx_path = os.path.join(self.root, s["idx"])
            # size check before mapping: a truncated file would otherwise
            # serve short/empty documents silently (memmap slices past
            # the end clip instead of raising)
            want_bin = s["n_tokens"] * self.dtype.itemsize
            want_idx = (s["n_docs"] + 1) * np.dtype(_IDX_DTYPE).itemsize
            got_bin = os.path.getsize(bin_path)
            got_idx = os.path.getsize(idx_path)
            if got_bin != want_bin or got_idx != want_idx:
                raise ValueError(
                    f"shard {s['file']} truncated or corrupt: "
                    f"bin {got_bin}B (manifest says {want_bin}B), "
                    f"idx {got_idx}B (manifest says {want_idx}B)")
            toks = np.memmap(bin_path, dtype=self.dtype, mode="r")
            idx = np.memmap(idx_path, dtype=_IDX_DTYPE, mode="r")
            self._maps[si] = (toks, idx)
        return self._maps[si]

    def locate(self, gid: int) -> tuple[int, int]:
        """Global doc id -> (shard index, local doc index)."""
        if not 0 <= gid < self.total_docs:
            raise IndexError(gid)
        si = int(np.searchsorted(self._doc_base, gid, side="right") - 1)
        return si, gid - int(self._doc_base[si])

    def doc(self, gid: int) -> np.ndarray:
        """Tokens of global document `gid` (zero-copy memmap view)."""
        si, li = self.locate(gid)
        toks, idx = self._shard_maps(si)
        return toks[int(idx[li]):int(idx[li + 1])]

    def doc_len(self, gid: int) -> int:
        """Length of global document `gid` without touching its tokens."""
        si, li = self.locate(gid)
        _, idx = self._shard_maps(si)
        return int(idx[li + 1] - idx[li])
