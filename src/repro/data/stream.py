"""Deterministic, checkpointable packed-batch stream over shard corpora.

`PackedStream` is the training-side iterator: it walks the corpus in a
seeded per-epoch document permutation, splits documents into <=seq_len
fragments, packs them with the best-fit policy (data/packing.py), and
emits fixed-shape batches forever (epochs wrap automatically).

Resume contract (docs/data_format.md "Resume guarantees"): the full
iterator state is four JSON-serializable fields --

    epoch    which permutation is active (perm = PRNG([seed, epoch]))
    cursor   next index into the epoch's document order
    pending  fragments fetched but not yet packed: [gid, start, end]
    seed     the stream's own seed (sanity-checked on load)

`state_dict()` snapshots the state *before* the next `next_batch()`
call, so save(state) -> load(state) -> next_batch() reproduces exactly
the batch an uninterrupted stream would have produced: resume is
bit-exact. The trainer serializes this blob into the checkpoint
manifest (`train/checkpoint.py` `extra["data"]`).
"""
from __future__ import annotations

import copy

import numpy as np

from . import packing
from .shards import ShardReader

STATE_VERSION = 1


class PackedStream:
    """Checkpointable best-fit packed batch iterator over a ShardReader."""

    def __init__(self, reader: ShardReader, *, seq_len: int, batch_size: int,
                 seed: int = 0, lookahead: int = 8):
        if reader.total_docs == 0:
            raise ValueError("empty corpus")
        self.reader = reader
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self.lookahead = max(1, lookahead)
        self._epoch = 0
        self._cursor = 0
        self._pending: list[list[int]] = []     # [gid, start, end]
        self._perm_epoch: int | None = None
        self._perm: np.ndarray | None = None

    # ------------------------------------------------------------- state
    def state_dict(self) -> dict:
        """JSON-serializable snapshot; `next_batch()` after a
        `load_state_dict(state_dict())` round-trip is bit-exact."""
        return {"version": STATE_VERSION, "seed": self.seed,
                "epoch": self._epoch, "cursor": self._cursor,
                "pending": copy.deepcopy(self._pending)}

    def load_state_dict(self, state: dict) -> None:
        """Restore a `state_dict()` snapshot (checkpoint resume)."""
        if state.get("version") != STATE_VERSION:
            raise ValueError(f"unsupported stream state version "
                             f"{state.get('version')!r}")
        if state.get("seed") != self.seed:
            raise ValueError(
                f"stream seed mismatch: checkpoint has {state.get('seed')}, "
                f"stream configured with {self.seed}")
        self._epoch = int(state["epoch"])
        self._cursor = int(state["cursor"])
        self._pending = [list(map(int, p)) for p in state["pending"]]
        self._perm_epoch = None     # recompute lazily

    # ------------------------------------------------------------ fetch
    def _epoch_perm(self) -> np.ndarray:
        if self._perm_epoch != self._epoch:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, self._epoch]))
            self._perm = rng.permutation(self.reader.total_docs)
            self._perm_epoch = self._epoch
        return self._perm

    def _fetch_doc(self) -> None:
        """Pull the next document of the epoch order into `pending`."""
        perm = self._epoch_perm()
        gid = int(perm[self._cursor])
        self._cursor += 1
        if self._cursor >= self.reader.total_docs:
            self._epoch += 1
            self._cursor = 0
        for s, e in packing.split_spans(self.reader.doc_len(gid),
                                        self.seq_len):
            self._pending.append([gid, s, e])

    def _fill_pending(self) -> None:
        while len(self._pending) < self.lookahead:
            self._fetch_doc()

    # ------------------------------------------------------------- emit
    def next_batch(self) -> packing.PackedBatch:
        """Pack and return the next (batch_size, seq_len) batch."""
        free = [self.seq_len] * self.batch_size
        rows: list[list[np.ndarray]] = [[] for _ in range(self.batch_size)]
        while True:
            self._fill_pending()
            window = self._pending[:self.lookahead]
            pick = packing.best_fit([e - s for _, s, e in window], free)
            if pick is None:
                break
            wi, row = pick
            gid, s, e = self._pending.pop(wi)
            toks = np.asarray(self.reader.doc(gid)[s:e], np.int32)
            rows[row].append(toks)
            free[row] -= e - s
        return packing.assemble(rows, self.seq_len)


class SyntheticStream:
    """Checkpointable adapter over the step-indexed `SyntheticLM`.

    Gives the synthetic fallback the same (next_batch / state_dict /
    load_state_dict) surface as `PackedStream`, so the trainer and the
    prefetcher treat both identically. Batches carry only "tokens" --
    byte-identical to the legacy step-indexed `batch_fn(step)` path
    (contiguous full-length rows need no segment masks).
    """

    def __init__(self, dataset):
        self.dataset = dataset
        self._step = 0

    def state_dict(self) -> dict:
        """Snapshot = the next step index (the stream is stateless)."""
        return {"version": STATE_VERSION, "seed": self.dataset.cfg.seed,
                "step": self._step}

    def load_state_dict(self, state: dict) -> None:
        """Restore the step cursor saved by `state_dict()`."""
        self._step = int(state["step"])

    def next_batch(self) -> packing.PackedBatch:
        """One synthetic (B, S) batch as a trivially-packed PackedBatch."""
        toks = self.dataset.global_batch(self._step)
        self._step += 1
        B, S = toks.shape
        return packing.PackedBatch(
            arrays={"tokens": toks.astype(np.int32)},
            meta={"pack_frac": 1.0, "n_fragments": B, "n_pad_tokens": 0})
