"""Deterministic synthetic LM data pipeline.

DCLM (the paper's corpus) is not available offline; precision-scheme
comparisons (the paper's claims) only need identical data across arms, so we
generate a *learnable* synthetic stream: a mixture of (a) a Zipf-distributed
unigram process and (b) first-order Markov bigram structure with
position-dependent transition mixing. Losses are therefore meaningfully
reducible below the unigram entropy and the BF16-vs-FP4 gap is measurable.

Properties required by the trainer:
  * deterministic: stream position is (seed, step, shard) -- restart-exact
  * shardable: each data-parallel shard draws a disjoint substream
  * stateless: no host-side iterator state beyond the integer step
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1
    n_states: int = 64          # bigram structure rank


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (ranks ** -cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # low-rank bigram: token -> state -> next-token distribution
        self._tok_state = rng.integers(0, cfg.n_states, size=V)
        self._state_shift = rng.integers(1, V - 1, size=cfg.n_states)

    def _batch_rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, shard]))

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """(global_batch/n_shards, seq_len) int32 tokens for one step/shard."""
        cfg = self.cfg
        B = cfg.global_batch // n_shards
        rng = self._batch_rng(step, shard)
        V = cfg.vocab_size
        first = rng.choice(V, size=(B, 1), p=self._unigram)
        toks = np.empty((B, cfg.seq_len), np.int64)
        toks[:, :1] = first
        # vectorized Markov walk: next = (prev + shift[state(prev)]) % V with
        # probability q, else fresh Zipf draw
        fresh = rng.choice(V, size=(B, cfg.seq_len), p=self._unigram)
        use_markov = rng.random((B, cfg.seq_len)) < 0.75
        for t in range(1, cfg.seq_len):
            prev = toks[:, t - 1]
            markov_next = (prev + self._state_shift[self._tok_state[prev]]) % V
            toks[:, t] = np.where(use_markov[:, t], markov_next, fresh[:, t])
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> np.ndarray:
        return self.batch(step, 0, 1)


def make_batch_fn(cfg: DataConfig):
    ds = SyntheticLM(cfg)
    return ds.global_batch
