"""Deterministic synthetic LM data pipeline.

DCLM (the paper's corpus) is not available offline; precision-scheme
comparisons (the paper's claims) only need identical data across arms, so we
generate a *learnable* synthetic stream: a mixture of (a) a Zipf-distributed
unigram process and (b) first-order Markov bigram structure with
position-dependent transition mixing. Losses are therefore meaningfully
reducible below the unigram entropy and the BF16-vs-FP4 gap is measurable.

Properties required by the trainer:
  * deterministic: stream position is (seed, step, shard) -- restart-exact
  * shardable: each data-parallel shard draws a disjoint substream
  * stateless: no host-side iterator state beyond the integer step
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1
    n_states: int = 64          # bigram structure rank


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self._unigram = (ranks ** -cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # low-rank bigram: token -> state -> next-token distribution
        self._tok_state = rng.integers(0, cfg.n_states, size=V)
        self._state_shift = rng.integers(1, V - 1, size=cfg.n_states)

    def _batch_rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, shard]))

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> np.ndarray:
        """(global_batch/n_shards, seq_len) int32 tokens for one step/shard."""
        cfg = self.cfg
        B = cfg.global_batch // n_shards
        rng = self._batch_rng(step, shard)
        V = cfg.vocab_size
        first = rng.choice(V, size=(B, 1), p=self._unigram)
        toks = np.empty((B, cfg.seq_len), np.int64)
        toks[:, :1] = first
        # vectorized Markov walk: next = (prev + shift[state(prev)]) % V with
        # probability q, else fresh Zipf draw
        fresh = rng.choice(V, size=(B, cfg.seq_len), p=self._unigram)
        use_markov = rng.random((B, cfg.seq_len)) < 0.75
        for t in range(1, cfg.seq_len):
            prev = toks[:, t - 1]
            markov_next = (prev + self._state_shift[self._tok_state[prev]]) % V
            toks[:, t] = np.where(use_markov[:, t], markov_next, fresh[:, t])
        return toks.astype(np.int32)

    def global_batch(self, step: int) -> np.ndarray:
        return self.batch(step, 0, 1)


def make_batch_fn(cfg: DataConfig):
    ds = SyntheticLM(cfg)
    return ds.global_batch


# --------------------------------------------------------------------------
# Document-shaped synthetic corpus -> on-disk shards (repro.data v2).
# Reuses the same Zipf+Markov process but emits variable-length documents,
# so the packing / shard pipeline has realistic length statistics to chew
# on (log-normal doc lengths, like web corpora).
# --------------------------------------------------------------------------

def synthetic_documents(cfg: DataConfig, n_docs: int, *,
                        mean_len: float = 200.0, sigma: float = 0.8,
                        min_len: int = 8, max_len: int | None = None):
    """Yield `n_docs` variable-length token documents (deterministic).

    Lengths are log-normal around `mean_len`; content comes from the same
    unigram/Markov process as `SyntheticLM` so losses stay meaningfully
    reducible. Document i depends only on (cfg.seed, i) -- regeneration
    is reproducible and order-independent.
    """
    ds = SyntheticLM(cfg)
    len_rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, 0xD0C5]))
    lens = np.exp(len_rng.normal(np.log(mean_len), sigma, size=n_docs))
    lens = np.clip(lens.astype(np.int64), min_len, max_len or 1 << 20)
    V = cfg.vocab_size
    for i in range(n_docs):
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 0xD0C, i]))
        L = int(lens[i])
        toks = np.empty(L, np.int64)
        toks[0] = rng.choice(V, p=ds._unigram)
        fresh = rng.choice(V, size=L, p=ds._unigram)
        use_markov = rng.random(L) < 0.75
        for t in range(1, L):
            prev = toks[t - 1]
            nxt = (prev + ds._state_shift[ds._tok_state[prev]]) % V
            toks[t] = nxt if use_markov[t] else fresh[t]
        yield toks.astype(np.int32)


def write_synthetic_shards(root: str, cfg: DataConfig, n_docs: int, *,
                           shard_tokens: int = 1 << 18, **doc_kw) -> str:
    """Materialize a synthetic corpus as a v1 shard directory.

    Returns the manifest path (`data/shards.py` layout). Used by the
    example driver's `--make-data`, the data benchmark, and tests.
    """
    from .shards import ShardWriter
    w = ShardWriter(root, cfg.vocab_size, shard_tokens=shard_tokens)
    for doc in synthetic_documents(cfg, n_docs, **doc_kw):
        w.add_document(doc)
    return w.finalize(meta={"source": "synthetic", "seed": cfg.seed,
                            "n_docs": n_docs})
