"""Best-fit sequence packing with segment-ID masks.

Documents rarely match the training sequence length, so rows of a packed
batch concatenate several document *fragments* back to back. Attention
between fragments is forbidden via per-token segment ids (threaded into
`models/attention.py` masks), positions restart at 0 inside each fragment
(RoPE sees every fragment as its own sequence), and the loss mask zeroes the
cross-fragment next-token predictions.

Conventions (docs/data_format.md "Packing semantics"):
  * ``segment_ids``: int32, 1-based per-row fragment index; 0 = padding.
  * ``positions``:   int32, 0-based within fragment; -1 on padding (the
    attention mask already treats negative key positions as invalid).
  * ``loss_mask``:   float32; label at position j counts iff j and j-1
    belong to the same non-pad segment (no cross-fragment prediction,
    no prediction of padding).

The placement policy is deterministic **best-fit with bounded
lookahead**: among the first `lookahead` pending fragments, place the
(fragment, row) pair with the tightest fit (smallest leftover space);
ties resolve to the earliest pending fragment, then the lowest row. The
batch closes when nothing in the window fits any row. Determinism is
what makes the stream checkpointable (data/stream.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def split_spans(length: int, seq_len: int) -> list[tuple[int, int]]:
    """Split a document of `length` tokens into (start, end) spans <= seq_len."""
    return [(s, min(s + seq_len, length))
            for s in range(0, length, seq_len)]


def best_fit(frag_lens: list[int], free: list[int]) -> tuple[int, int] | None:
    """Pick (fragment index, row index) with the tightest fit.

    `frag_lens` are the lengths of the lookahead window (pending order);
    `free` the remaining space per row. Returns None when nothing fits.
    """
    best: tuple[int, int, int] | None = None      # (leftover, wi, row)
    for wi, ln in enumerate(frag_lens):
        for r, fr in enumerate(free):
            if fr >= ln:
                key = (fr - ln, wi, r)
                if best is None or key < best:
                    best = key
    if best is None:
        return None
    return best[1], best[2]


@dataclasses.dataclass
class PackedBatch:
    """One packed batch: jit-ready arrays plus host-side packing stats."""

    arrays: dict            # tokens/segment_ids/positions/loss_mask (B,S)
    meta: dict              # pack_frac, n_fragments, n_pad_tokens


def assemble(rows: list[list[np.ndarray]], seq_len: int) -> PackedBatch:
    """Concatenate each row's fragments into fixed (B, S) arrays.

    Rows shorter than `seq_len` are right-padded with token 0,
    segment 0, position -1, loss_mask 0.
    """
    B, S = len(rows), seq_len
    tokens = np.zeros((B, S), np.int32)
    segs = np.zeros((B, S), np.int32)
    pos = np.full((B, S), -1, np.int32)
    n_frags = 0
    for r, frags in enumerate(rows):
        at = 0
        for si, frag in enumerate(frags):
            ln = len(frag)
            tokens[r, at:at + ln] = frag
            segs[r, at:at + ln] = si + 1
            pos[r, at:at + ln] = np.arange(ln, dtype=np.int32)
            at += ln
            n_frags += 1
    # label at j is valid iff j-1 and j share a non-pad segment
    same = np.zeros((B, S), bool)
    same[:, 1:] = (segs[:, 1:] == segs[:, :-1]) & (segs[:, 1:] > 0)
    loss_mask = same.astype(np.float32)
    n_real = int((segs > 0).sum())
    return PackedBatch(
        arrays={"tokens": tokens, "segment_ids": segs, "positions": pos,
                "loss_mask": loss_mask},
        meta={"pack_frac": n_real / float(B * S), "n_fragments": n_frags,
              "n_pad_tokens": B * S - n_real})
