"""Double-buffered host->device prefetch for checkpointable streams.

A background producer thread runs the host-side pipeline (shard reads +
packing -- all numpy, GIL-friendly) into a bounded queue; the consumer
side stages batches onto the device with a sharding-aware `place_fn`
(typically `jax.device_put` with `dist/sharding.py` batch shardings) so
the next batch's H2D transfer is in flight while the current step runs.

Checkpoint correctness with a read-ahead producer: every queue item
carries the stream state snapshot taken *after* that batch was drawn.
`state_dict()` returns the snapshot of the most recently *consumed*
batch -- never the producer's (further ahead) live state -- so a resume
replays exactly the batches the trainer did not see. `restart(state)`
flushes the queue and reseeks the underlying stream (used by the
trainer's failure-recovery path).

Restart is fenced by a generation counter (DESIGN.md §15): each producer
thread owns its generation's queue and stop event, created fresh per
(re)start.  A producer stuck in a slow `stream.next_batch()` when
`restart` times out its join can therefore never push a stale batch --
or a phantom error -- into the new generation: it only holds references
to its own, now-orphaned, queue/event, and exits at its next stop check.
(The stuck call itself still holds the old stream position in its stack;
the reseek happens regardless, and the fence guarantees nothing it
produces escapes.)

Health counters (`stats()`, reset per call) feed `repro.obs` records:
stall_ms (consumer time blocked waiting on the queue), queue_depth
(occupancy when the consumer arrived), pack_frac (mean packing
efficiency of the consumed batches).
"""
from __future__ import annotations

import queue
import threading
import time

from repro.chaos.hooks import chaos_point

from .packing import PackedBatch


class DevicePrefetcher:
    """Wrap a checkpointable stream with an async producer + device staging.

    `stream` must expose next_batch()/state_dict()/load_state_dict()
    (PackedStream, SyntheticStream). `place_fn(arrays) -> arrays` stages a
    host batch onto devices; identity by default.  `stall_timeout` bounds
    the consumer's wait on an empty queue (a wedged producer surfaces as
    TimeoutError, not a hang); `join_timeout` bounds how long restart/stop
    wait for the producer thread before fencing it off.
    """

    def __init__(self, stream, place_fn=None, depth: int = 2,
                 stall_timeout: float = 60.0, join_timeout: float = 5.0):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.stream = stream
        self.place_fn = place_fn or (lambda arrays: arrays)
        self.depth = depth
        self.stall_timeout = stall_timeout
        self.join_timeout = join_timeout
        self._gen = 0
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._consumed_state = stream.state_dict()
        self._staged: PackedBatch | None = None
        self._staged_state: dict | None = None
        self._error: BaseException | None = None
        # rolling health counters, drained by stats()
        self._stall_ms = 0.0
        self._depth_sum = 0
        self._pack_sum = 0.0
        self._n_batches = 0
        self._start()

    # ---------------------------------------------------------- producer
    def _start(self):
        # fresh queue + stop event per generation: an old producer that
        # outlived its join timeout holds only its own generation's
        # objects and can never touch these
        self._gen += 1
        self._q = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._produce, args=(self._gen, self._q, self._stop),
            daemon=True)
        self._thread.start()

    def _produce(self, gen: int, q: queue.Queue, stop: threading.Event):
        try:
            while not stop.is_set():
                chaos_point("prefetch.tick", gen=gen)
                batch = self.stream.next_batch()
                state = self.stream.state_dict()
                while not stop.is_set():
                    try:
                        q.put((batch, state), timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 - surfaced to consumer
            if gen == self._gen:        # stale generations report nothing
                self._error = e
            stop.set()

    def _pop(self, block: bool) -> tuple[PackedBatch, dict] | None:
        # Drain residual good batches before surfacing a producer death:
        # the error concerns batches the producer could NOT draw, so ones
        # it already queued are still valid (and checkpoint-consistent).
        try:
            return self._q.get_nowait()
        except queue.Empty:
            pass
        if not block:
            return None            # opportunistic staging pop never raises
        if self._error is not None:
            raise RuntimeError("prefetch producer died") from self._error
        try:
            return self._q.get(timeout=self.stall_timeout)
        except queue.Empty:
            if self._error is not None:
                raise RuntimeError("prefetch producer died") from self._error
            raise TimeoutError(f"prefetch producer stalled > "
                               f"{self.stall_timeout}s")

    # ---------------------------------------------------------- consumer
    def next_batch(self) -> PackedBatch:
        """Next batch with arrays already staged via `place_fn`."""
        t0 = time.perf_counter()
        self._depth_sum += self._q.qsize() + (self._staged is not None)
        if self._staged is not None:
            batch, state = self._staged, self._staged_state
            self._staged = None
        else:
            batch, state = self._pop(block=True)
            batch = PackedBatch(self.place_fn(batch.arrays), batch.meta)
        self._stall_ms += (time.perf_counter() - t0) * 1e3
        self._consumed_state = state
        self._pack_sum += batch.meta.get("pack_frac", 1.0)
        self._n_batches += 1
        # double buffering: stage the following batch on-device now, so
        # its H2D transfer overlaps the step that consumes `batch`
        nxt = self._pop(block=False)
        if nxt is not None:
            nb, ns = nxt
            self._staged = PackedBatch(self.place_fn(nb.arrays), nb.meta)
            self._staged_state = ns
        return batch

    def state_dict(self) -> dict:
        """Stream state as of the last *consumed* batch (checkpoint-safe)."""
        return self._consumed_state

    def load_state_dict(self, state: dict) -> None:
        """Alias for `restart` (same surface as the raw streams)."""
        self.restart(state)

    def restart(self, state: dict) -> None:
        """Flush read-ahead and reseek the stream to `state`.

        A producer stuck past `join_timeout` is abandoned behind the
        generation fence rather than waited on forever (it exits on its
        own at its next stop-event check)."""
        self.stop()
        self.stream.load_state_dict(state)
        self._consumed_state = self.stream.state_dict()
        self._staged = None
        self._staged_state = None
        self._error = None
        self._start()

    def stats(self) -> dict:
        """Drain health counters accumulated since the previous call."""
        n = max(1, self._n_batches)
        out = {"stall_ms": self._stall_ms / n,
               "queue_depth": self._depth_sum / n,
               "pack_frac": self._pack_sum / n}
        self._stall_ms = 0.0
        self._depth_sum = 0
        self._pack_sum = 0.0
        self._n_batches = 0
        return out

    def stop(self) -> None:
        """Stop the producer thread (idempotent; bounded wait)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.join_timeout)
            self._thread = None
