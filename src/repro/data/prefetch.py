"""Double-buffered host->device prefetch for checkpointable streams.

A background producer thread runs the host-side pipeline (shard reads +
packing -- all numpy, GIL-friendly) into a bounded queue; the consumer
side stages batches onto the device with a sharding-aware `place_fn`
(typically `jax.device_put` with `dist/sharding.py` batch shardings) so
the next batch's H2D transfer is in flight while the current step runs.

Checkpoint correctness with a read-ahead producer: every queue item
carries the stream state snapshot taken *after* that batch was drawn.
`state_dict()` returns the snapshot of the most recently *consumed*
batch -- never the producer's (further ahead) live state -- so a resume
replays exactly the batches the trainer did not see. `restart(state)`
flushes the queue and reseeks the underlying stream (used by the
trainer's failure-recovery path).

Health counters (`stats()`, reset per call) feed `repro.obs` records:
stall_ms (consumer time blocked waiting on the queue), queue_depth
(occupancy when the consumer arrived), pack_frac (mean packing
efficiency of the consumed batches).
"""
from __future__ import annotations

import queue
import threading
import time

from .packing import PackedBatch


class DevicePrefetcher:
    """Wrap a checkpointable stream with an async producer + device staging.

    `stream` must expose next_batch()/state_dict()/load_state_dict()
    (PackedStream, SyntheticStream). `place_fn(arrays) -> arrays` stages a
    host batch onto devices; identity by default.
    """

    def __init__(self, stream, place_fn=None, depth: int = 2):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.stream = stream
        self.place_fn = place_fn or (lambda arrays: arrays)
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._consumed_state = stream.state_dict()
        self._staged: PackedBatch | None = None
        self._staged_state: dict | None = None
        self._error: BaseException | None = None
        # rolling health counters, drained by stats()
        self._stall_ms = 0.0
        self._depth_sum = 0
        self._pack_sum = 0.0
        self._n_batches = 0
        self._start()

    # ---------------------------------------------------------- producer
    def _start(self):
        self._stop.clear()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            while not self._stop.is_set():
                batch = self.stream.next_batch()
                state = self.stream.state_dict()
                while not self._stop.is_set():
                    try:
                        self._q.put((batch, state), timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 - surfaced to consumer
            self._error = e
            self._stop.set()

    def _pop(self, block: bool) -> tuple[PackedBatch, dict] | None:
        if self._error is not None:
            raise RuntimeError("prefetch producer died") from self._error
        try:
            return self._q.get(timeout=60.0) if block else \
                self._q.get_nowait()
        except queue.Empty:
            if self._error is not None:
                raise RuntimeError("prefetch producer died") from self._error
            if block:
                raise TimeoutError("prefetch producer stalled > 60s")
            return None

    # ---------------------------------------------------------- consumer
    def next_batch(self) -> PackedBatch:
        """Next batch with arrays already staged via `place_fn`."""
        t0 = time.perf_counter()
        self._depth_sum += self._q.qsize() + (self._staged is not None)
        if self._staged is not None:
            batch, state = self._staged, self._staged_state
            self._staged = None
        else:
            batch, state = self._pop(block=True)
            batch = PackedBatch(self.place_fn(batch.arrays), batch.meta)
        self._stall_ms += (time.perf_counter() - t0) * 1e3
        self._consumed_state = state
        self._pack_sum += batch.meta.get("pack_frac", 1.0)
        self._n_batches += 1
        # double buffering: stage the following batch on-device now, so
        # its H2D transfer overlaps the step that consumes `batch`
        nxt = self._pop(block=False)
        if nxt is not None:
            nb, ns = nxt
            self._staged = PackedBatch(self.place_fn(nb.arrays), nb.meta)
            self._staged_state = ns
        return batch

    def state_dict(self) -> dict:
        """Stream state as of the last *consumed* batch (checkpoint-safe)."""
        return self._consumed_state

    def load_state_dict(self, state: dict) -> None:
        """Alias for `restart` (same surface as the raw streams)."""
        self.restart(state)

    def restart(self, state: dict) -> None:
        """Flush read-ahead and reseek the stream to `state`."""
        self.stop()
        self.stream.load_state_dict(state)
        self._consumed_state = self.stream.state_dict()
        self._staged = None
        self._staged_state = None
        self._error = None
        self._q = queue.Queue(maxsize=self.depth)
        self._start()

    def stats(self) -> dict:
        """Drain health counters accumulated since the previous call."""
        n = max(1, self._n_batches)
        out = {"stall_ms": self._stall_ms / n,
               "queue_depth": self._depth_sum / n,
               "pack_frac": self._pack_sum / n}
        self._stall_ms = 0.0
        self._depth_sum = 0
        self._pack_sum = 0.0
        self._n_batches = 0
        return out

    def stop(self) -> None:
        """Stop the producer thread (idempotent)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
