"""rwkv6-1.6b [ssm]: 24L, d=2048, attn-free (RWKV6 'Finch' time-mix with
data-dependent decay), ff=7168, vocab=65536. [arXiv:2404.05892; unverified]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65536,
        rwkv=True, ssm_head_dim=64,
        act="relu", tie_embeddings=False,
        source="arXiv:2404.05892",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_head_dim=16, attn_chunk=32, loss_chunk=32,
        remat=False)


register("rwkv6-1.6b", full, smoke)
