"""Config registry: importing this package registers all architectures."""
from . import (gemma2_9b, gemma3_27b, llama2_paper, minicpm3_4b,
               moonshot_16b, pixtral_12b, qwen3_moe_30b, qwen15_32b,
               rwkv6_1p6b, whisper_medium, zamba2_7b)
from .base import SHAPES, ArchConfig, ShapeSpec, get_config, list_archs

ASSIGNED_ARCHS = [
    "whisper-medium", "qwen1.5-32b", "gemma3-27b", "minicpm3-4b",
    "gemma2-9b", "qwen3-moe-30b-a3b", "moonshot-v1-16b-a3b", "zamba2-7b",
    "pixtral-12b", "rwkv6-1.6b",
]

__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "get_config", "list_archs",
           "ASSIGNED_ARCHS"]
