"""The paper's own LLaMA-2 experiment configs (§4.1): 400M (Fig. 1),
1.3B / 7B / 13B (Fig. 5, Tables 2-3), trained on DCLM with seq 2048.
These are the models the FP4 recipe was validated on."""
from .base import ArchConfig, register


def _llama(name, n_layers, d_model, n_heads, d_ff) -> ArchConfig:
    return ArchConfig(
        name=name, family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_heads, d_ff=d_ff, vocab_size=32000,
        act="silu", tie_embeddings=False, rope_theta=10_000.0,
        source="paper §4.1 (LLaMA-2 family)",
    )


def llama2_400m() -> ArchConfig:
    return _llama("llama2-400m", 24, 1024, 16, 2816)


def llama2_1p3b() -> ArchConfig:
    return _llama("llama2-1.3b", 24, 2048, 16, 5504)


def llama2_7b() -> ArchConfig:
    return _llama("llama2-7b", 32, 4096, 32, 11008)


def llama2_13b() -> ArchConfig:
    return _llama("llama2-13b", 40, 5120, 40, 13824)


def _smoke() -> ArchConfig:
    return _llama("llama2-smoke", 2, 64, 4, 128).replace(
        vocab_size=256, attn_chunk=32, loss_chunk=32, remat=False)


register("llama2-400m", llama2_400m, _smoke)
register("llama2-1.3b", llama2_1p3b, _smoke)
register("llama2-7b", llama2_7b, _smoke)
register("llama2-13b", llama2_13b, _smoke)
