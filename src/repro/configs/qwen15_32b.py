"""qwen1.5-32b [dense]: 64L, d=5120, 40H (GQA kv=40), ff=27392,
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0, act="silu",
        tie_embeddings=False,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, attn_chunk=32, loss_chunk=32, remat=False)


register("qwen1.5-32b", full, smoke)
