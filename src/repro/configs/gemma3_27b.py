"""gemma3-27b [dense]: 62L, d=5376, 32H (GQA kv=16), ff=21504,
vocab=262144. 5:1 local:global attention (window 1024), dual rope bases
(10k local / 1M global), qk-norm, sandwich norms, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        attn_pattern="local_global_5_1", window_size=1024,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0,
        qk_norm=True, norm_plus_one=True, embed_scale_sqrt_d=True,
        act="gelu", tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window_size=16, attn_chunk=32,
        loss_chunk=32, remat=False)


register("gemma3-27b", full, smoke)
