"""qwen3-moe-30b-a3b [moe]: 48L, d=2048, 32H (GQA kv=4), per-expert ff=768,
vocab=151936, MoE 128 experts top-8, qk-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936,
        n_experts=128, top_k=8, moe_d_ff=768,
        qk_norm=True, rope_theta=1_000_000.0, act="silu",
        tie_embeddings=False,
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, top_k=2, moe_d_ff=64,
        attn_chunk=32, loss_chunk=32, remat=False)


register("qwen3-moe-30b-a3b", full, smoke)
