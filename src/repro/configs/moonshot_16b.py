"""moonshot-v1-16b-a3b [moe]: 48L, d=2048, 16H (GQA kv=16), per-expert
ff=1408, vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        n_experts=64, top_k=6, moe_d_ff=1408,
        rope_theta=50_000.0, act="silu", tie_embeddings=False,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=256, n_experts=8, top_k=2, moe_d_ff=64,
        attn_chunk=32, loss_chunk=32, remat=False)


register("moonshot-v1-16b-a3b", full, smoke)
