"""Architecture configs + the assigned input-shape grid.

Every assigned architecture gets one `ArchConfig` (exact numbers from the
assignment table) plus a `smoke()` reduction used by CPU tests. Shapes are
the four assigned cells; `applicable_shapes()` encodes the skip rules
(decode for encoder-only, long_500k for pure full-attention archs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

# ---------------------------------------------------------------------------
# Shapes (assignment): seq_len x global_batch.
# train_* lowers train_step; prefill_* lowers serve prefill;
# decode_*/long_* lower serve_step (1 new token against a seq_len KV cache).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None          # default d_model // n_heads

    # attention variants
    attn_pattern: str = "global"         # global | local_global_5_1 | alt_local_global
    window_size: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_theta_local: float | None = None  # gemma3: local layers use 10k, global 1M

    # MLA (minicpm3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    shared_attn_every: int = 0           # zamba2: shared attn block cadence
    rwkv: bool = False

    # enc-dec (whisper)
    enc_layers: int = 0

    # frontend
    frontend: str = "tokens"             # tokens | embeddings (stubbed modality)

    # norm / activation / misc
    norm_type: str = "rmsnorm"           # rmsnorm | layernorm
    norm_plus_one: bool = False          # gemma convention
    act: str = "silu"                    # silu | gelu (glu variants implied)
    tie_embeddings: bool = True
    embed_scale_sqrt_d: bool = False     # gemma multiplies embeddings by sqrt(d)

    # execution knobs
    remat: bool = True
    remat_policy: str = "full"       # full | dots (save dot outputs)
    scan_layers: bool = False        # stack repeating layer groups (dry-run)
    embed_onehot: bool = False       # vocab-parallel one-hot embedding
    mla_pad_heads: int = 0           # pad MLA heads for TP divisibility
    attn_chunk: int = 1024
    loss_chunk: int = 512
    cache_dtype: str = "bfloat16"        # bfloat16 | float8_e4m3fn
    max_decode_len: int = 0              # 0 = use shape seq_len

    source: str = ""                     # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ---- layer plan ------------------------------------------------------
    def layer_plan(self) -> list[dict]:
        """One dict per decoder layer describing the block stack."""
        plan = []
        for i in range(self.n_layers):
            if self.rwkv:
                plan.append({"kind": "rwkv"})
                continue
            if self.shared_attn_every:
                if (i + 1) % self.shared_attn_every == 0:
                    plan.append({"kind": "shared_attn"})
                else:
                    plan.append({"kind": "ssm"})
                continue
            if self.ssm_state and not self.shared_attn_every:
                plan.append({"kind": "ssm"})
                continue
            entry = {"kind": "mla" if self.use_mla else "attn"}
            if self.attn_pattern == "local_global_5_1":
                is_global = (i + 1) % 6 == 0
            elif self.attn_pattern == "alt_local_global":
                is_global = i % 2 == 1
            else:
                is_global = True
            entry["window"] = None if is_global else self.window_size
            entry["rope_theta"] = (self.rope_theta if is_global or
                                   self.rope_theta_local is None
                                   else self.rope_theta_local)
            entry["ffn"] = "moe" if self.n_experts else "dense"
            plan.append(entry)
        return plan

    def applicable_shapes(self) -> list[str]:
        """Assigned-shape skip rules (documented in DESIGN.md §5)."""
        shapes = ["train_4k", "prefill_32k", "decode_32k"]
        subquadratic = (self.rwkv or self.ssm_state > 0 or
                        self.attn_pattern in ("local_global_5_1",
                                              "alt_local_global"))
        if subquadratic:
            shapes.append("long_500k")
        return shapes

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}
_SMOKE: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str, full: Callable[[], ArchConfig],
             smoke: Callable[[], ArchConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
