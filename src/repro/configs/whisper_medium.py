"""whisper-medium [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings). 24L enc + 24L dec, d=1024, 16H MHA, ff=4096, vocab=51865.
[arXiv:2212.04356; unverified]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        frontend="embeddings", norm_type="layernorm", act="gelu",
        qkv_bias=True, tie_embeddings=True,
        source="arXiv:2212.04356",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, attn_chunk=32, loss_chunk=32, remat=False)


register("whisper-medium", full, smoke)
