"""minicpm3-4b [dense]: 62L, d=2560, 40H (GQA kv=40), ff=6400,
vocab=73448, Multi-head Latent Attention (q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64). [hf:openbmb/MiniCPM3-4B; hf]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        use_mla=True, q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
        act="silu", tie_embeddings=True,
        source="hf:openbmb/MiniCPM3-4B",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
        attn_chunk=32, loss_chunk=32, remat=False)


register("minicpm3-4b", full, smoke)
