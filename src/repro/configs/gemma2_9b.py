"""gemma2-9b [dense]: 42L, d=3584, 16H (GQA kv=8), ff=14336, vocab=256000.
Alternating local/global attention (window 4096), attn softcap 50, final
logit softcap 30, sandwich norms. [arXiv:2408.00118; hf]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=14336, vocab_size=256000,
        attn_pattern="alt_local_global", window_size=4096,
        attn_softcap=50.0, final_softcap=30.0,
        norm_plus_one=True, embed_scale_sqrt_d=True,
        act="gelu", tie_embeddings=True,
        source="arXiv:2408.00118",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window_size=16, attn_chunk=32,
        loss_chunk=32, remat=False)


register("gemma2-9b", full, smoke)
