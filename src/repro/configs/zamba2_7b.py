"""zamba2-7b [hybrid]: 81L, d=3584, ff=14336, vocab=32000, ssm_state=64.
Mamba2 backbone + shared-weight full-attention block every 6th layer
(32H attention in the shared block). [arXiv:2411.15242; unverified]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        shared_attn_every=6,
        act="silu", tie_embeddings=True,
        source="arXiv:2411.15242",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        attn_chunk=32, loss_chunk=32, remat=False)


register("zamba2-7b", full, smoke)
