"""pixtral-12b [vlm]: 40L, d=5120, 32H (GQA kv=8), ff=14336, vocab=131072.
Mistral-Nemo backbone (head_dim=128); pixtral-ViT frontend stubbed --
input_specs provides precomputed patch+text embeddings.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072,
        frontend="embeddings", rope_theta=1_000_000.0, act="silu",
        tie_embeddings=False,
        source="hf:mistralai/Pixtral-12B-2409",
    )


def smoke() -> ArchConfig:
    return full().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, attn_chunk=32, loss_chunk=32, remat=False)


register("pixtral-12b", full, smoke)
