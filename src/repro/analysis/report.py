"""Generate EXPERIMENTS.md §Roofline table from dry-run artifacts.

Prefers `artifacts/dryrun2` (collective parser with while-body trip-count
multiplication) and falls back to `artifacts/dryrun` (pre-fix: in-scan
collectives counted once -- a lower bound, flagged with *).
"""
from __future__ import annotations

import glob
import json
import os


def merged_artifacts(primary="artifacts/dryrun2", fallback="artifacts/dryrun",
                     mesh="single"):
    rows = {}
    for d, flag in ((fallback, True), (primary, False)):
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            name = os.path.basename(path)
            if "sweep_log" in name or name.count("__") != 2:
                continue  # variants have tags; baselines only
            with open(path) as f:
                r = json.load(f)
            if r.get("mesh") != mesh and not r.get("skipped"):
                continue
            if r.get("skipped") and r.get("mesh", mesh) != mesh:
                continue
            key = (r["arch"], r["shape"])
            r["_stale_collectives"] = flag
            rows[key] = r
    return rows


def render(mesh="single") -> str:
    rows = merged_artifacts(mesh=mesh)
    lines = [
        "| arch | shape | compute(s) | memory(s) | collective(s) | dominant "
        "| useful | peak GB* | MFU bound | HW util |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for (arch, shape) in sorted(rows, key=lambda k: (k[0], order.index(k[1]))):
        r = rows[(arch, shape)]
        if r.get("skipped"):
            lines.append(f"| {arch} | {shape} | — | — | — | skipped"
                         f" (full-attn @512K) | — | — | — | — |")
            continue
        stale = "†" if r.get("_stale_collectives") else ""
        rl = r["roofline"]
        hw = r.get("hw_util_bound", 0.0)
        lines.append(
            f"| {arch} | {shape} | {rl['compute_fp4_s']:.3g} | "
            f"{rl['memory_s']:.3g} | {rl['collective_s']:.3g}{stale} | "
            f"**{rl['dominant']}** | {r['flops']['useful_ratio']:.2f} | "
            f"{r['memory_analysis']['peak_estimate_gb']:.1f} | "
            f"{r['mfu_bound']:.3f} | {hw:.3f} |")
    return "\n".join(lines)


def inject(markdown_path="EXPERIMENTS.md", marker="<!-- ROOFLINE_TABLE -->",
           content: str | None = None):
    content = content or render()
    with open(markdown_path) as f:
        text = f.read()
    if marker not in text:
        raise ValueError(f"{marker} not found")
    text = text.replace(marker, content, 1)
    with open(markdown_path, "w") as f:
        f.write(text)


if __name__ == "__main__":
    print(render())
