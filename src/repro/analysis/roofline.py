"""Three-term roofline from the compiled dry-run artifact (TPU v5e targets).

    compute    = FLOPs / (chips * peak FLOP/s)
    memory     = HBM bytes / (chips * HBM bandwidth)
    collective = wire bytes / (chips * ICI link bandwidth)

Hardware constants (per assignment): 197 TFLOP/s bf16 per chip (394 TOPS
int8 -- the FP4-as-int8 GeMM path), 819 GB/s HBM, ~50 GB/s/link ICI.

Two compute terms are reported:
  * compute_bf16   -- all FLOPs at the bf16 peak (paper-agnostic baseline)
  * compute_fp4    -- fp4-GeMM FLOPs at the int8 peak, rest at bf16 peak
    (the paper's speedup claim expressed as a roofline term)
"""
from __future__ import annotations

import dataclasses

PEAK_BF16 = 197e12          # FLOP/s per chip
PEAK_INT8 = 394e12          # FP4-as-int8 MXU path
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (per chip, one direction)


@dataclasses.dataclass
class Roofline:
    compute_bf16_s: float
    compute_fp4_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_fp4_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound: max of the three terms (perfect overlap)"""
        return max(self.compute_fp4_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_bf16_s": self.compute_bf16_s,
            "compute_fp4_s": self.compute_fp4_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def roofline_terms(*, hlo_flops_per_dev: float, corrected_flops_per_dev: float,
                   hbm_bytes_per_dev: float, wire_bytes_per_dev: float,
                   fp4_fraction: float) -> Roofline:
    """fp4_fraction: share of corrected FLOPs running on the int8 path."""
    f = corrected_flops_per_dev
    compute_bf16 = f / PEAK_BF16
    compute_fp4 = (f * fp4_fraction) / PEAK_INT8 + \
        (f * (1 - fp4_fraction)) / PEAK_BF16
    return Roofline(
        compute_bf16_s=compute_bf16,
        compute_fp4_s=compute_fp4,
        memory_s=hbm_bytes_per_dev / HBM_BW,
        collective_s=wire_bytes_per_dev / ICI_BW,
    )


def mfu(model_flops_per_dev: float, step_time_s: float,
        peak: float = PEAK_BF16) -> float:
    """MFU against the bf16 peak. NOTE: with the fp4 GeMM fraction priced at
    the 2x int8 peak, this can legitimately exceed 1.0 -- that excess IS the
    paper's speedup expressed as utilization."""
    if step_time_s <= 0:
        return 0.0
    return model_flops_per_dev / (step_time_s * peak)


def hw_utilization(corrected_flops_per_dev: float, step_time_s: float,
                   fp4_fraction: float) -> float:
    """Silicon utilization (<= 1): executed FLOPs at the blended peak the
    program can actually reach."""
    if step_time_s <= 0:
        return 0.0
    blended = fp4_fraction * PEAK_INT8 + (1 - fp4_fraction) * PEAK_BF16
    return corrected_flops_per_dev / (step_time_s * blended)
