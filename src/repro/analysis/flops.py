"""Analytic FLOP model per (arch config x shape x mode).

Returns *useful algorithmic* FLOPs (the MODEL_FLOPS of the roofline spec:
6*N*D for dense training, 6*N_active*D for MoE, attention/SSD/WKV dynamic
terms added), plus:

  * fp4_gemm_flops -- the subset executed through fp4_linear (these run on
    the int8 MXU at 2x bf16 throughput on the TPU adaptation);
  * scan_corrections -- analytic body FLOPs x (trips-1) for each inner
    `lax.scan` (XLA cost_analysis counts while bodies once; layer loops are
    unrolled so only these algorithmic scans need correction). Train-mode
    scans inside remat are multiplied by 4 (fwd + remat-recompute + 2x bwd),
    serve-mode by 1 -- documented estimate, raw numbers kept alongside.

All numbers are GLOBAL (whole-cluster); divide by chip count for per-device.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass
class ScanCorrection:
    name: str
    body_flops: float        # per execution of the body, global
    trips: int
    mode_factor: float       # 1 serve, 4 train (fwd+remat+2bwd)

    @property
    def correction(self) -> float:
        return self.body_flops * (self.trips - 1) * self.mode_factor

    @property
    def total(self) -> float:
        return self.body_flops * self.trips * self.mode_factor


def _attn_linear_ptok(cfg: ArchConfig) -> float:
    dh = cfg.resolved_head_dim
    return 2.0 * cfg.d_model * dh * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)


def _mla_linear_ptok(cfg: ArchConfig) -> float:
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    terms = (cfg.d_model * cfg.q_lora_rank +
             cfg.q_lora_rank * H * qk +
             cfg.d_model * (cfg.kv_lora_rank + cfg.qk_rope_dim) +
             cfg.kv_lora_rank * H * (cfg.qk_nope_dim + cfg.v_head_dim) +
             H * cfg.v_head_dim * cfg.d_model)
    return 2.0 * terms


def _ffn_ptok(cfg: ArchConfig, d_ff: int | None = None) -> float:
    f = d_ff or cfg.d_ff
    n_mats = 3  # glu
    return 2.0 * n_mats * cfg.d_model * f


def _moe_ptok(cfg: ArchConfig) -> float:
    router = 2.0 * cfg.d_model * cfg.n_experts
    return router + cfg.top_k * 2.0 * 3 * cfg.d_model * cfg.moe_d_ff


def _ssm_linear_ptok(cfg: ArchConfig) -> float:
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    return 2.0 * cfg.d_model * (3 * di + 2 * cfg.ssm_state + H)


def _rwkv_linear_ptok(cfg: ArchConfig) -> float:
    D, F = cfg.d_model, cfg.d_ff
    return 2.0 * (6 * D * D + 2 * 64 * D + 2 * D * F)


def _attn_dynamic(cfg: ArchConfig, S_q: int, S_kv: int, window, causal=True):
    """Useful score+PV FLOPs for one layer, per sequence (not per token)."""
    dh = cfg.resolved_head_dim
    hd = cfg.n_heads * dh
    if S_q == 1:  # decode
        return 4.0 * S_kv * hd
    if window and S_kv > window:
        return 4.0 * S_q * window * hd * (0.5 if causal else 1.0) * 2
    eff = 0.5 if causal else 1.0
    return 4.0 * S_q * S_kv * hd * eff


def _ssd_dynamic(cfg: ArchConfig, S: int) -> float:
    """Per layer per sequence (useful)."""
    di = cfg.ssm_expand * cfg.d_model
    H = di // cfg.ssm_head_dim
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    L = min(cfg.ssm_chunk, S)
    nc = max(1, S // L)
    per_chunk = 2 * L * L * N + 2 * L * L * H * P + 6 * L * H * P * N
    return nc * per_chunk


def _wkv_dynamic(cfg: ArchConfig, S: int) -> float:
    hd = cfg.ssm_head_dim
    return 4.0 * S * cfg.d_model * hd


def model_flops(cfg: ArchConfig, shape: ShapeSpec, mode: str) -> dict:
    """mode: 'train' | 'prefill' | 'decode'. Returns global-FLOPs dict."""
    B = shape.global_batch
    S = shape.seq_len
    plan = cfg.layer_plan()
    mode_factor = 3.0 if mode == "train" else 1.0
    train = mode == "train"

    lin_ptok = 0.0       # per-token linear fwd flops (fp4 sites)
    dyn_pseq = 0.0       # per-sequence dynamic fwd flops (non-fp4)
    scans: list[ScanCorrection] = []

    if cfg.enc_layers:  # whisper enc-dec
        Senc = Sdec = (S // 2 if mode != "decode" else S)
        D, F = cfg.d_model, cfg.d_ff
        enc_lin = cfg.enc_layers * (2.0 * 4 * D * D + 2.0 * 2 * D * F)
        dec_lin = cfg.n_layers * (2.0 * 8 * D * D + 2.0 * 2 * D * F)
        if mode == "decode":
            S_cache, Smem = S, S // 2
            lin_decode = cfg.n_layers * (2.0 * 8 * D * D + 2.0 * 2 * D * F)
            dyn = cfg.n_layers * (_attn_dynamic(cfg, 1, S_cache, None) +
                                  _attn_dynamic(cfg, 1, Smem, None, False))
            head = 2.0 * D * cfg.vocab_size
            total = B * (lin_decode + dyn + head)
            return {"model_flops": total, "fp4_gemm_flops": B * lin_decode,
                    "scan_corrections": [], "tokens": B,
                    "layers_fwd_flops": B * (lin_decode + dyn)}
        dyn = (cfg.enc_layers * _attn_dynamic(cfg, Senc, Senc, None, False) +
               cfg.n_layers * (_attn_dynamic(cfg, Sdec, Sdec, None) +
                               _attn_dynamic(cfg, Sdec, Senc, None, False)))
        head = 2.0 * D * cfg.vocab_size * Sdec
        fwd = B * (enc_lin * Senc + dec_lin * Sdec + dyn + head)
        fp4 = B * (enc_lin * Senc + dec_lin * Sdec)
        if Senc > 2 * cfg.attn_chunk:
            trips = -(-Senc // cfg.attn_chunk)
            body = 4.0 * B * cfg.n_heads * cfg.resolved_head_dim * Senc * \
                cfg.attn_chunk
            n_scans = cfg.enc_layers + 2 * cfg.n_layers
            scans.append(ScanCorrection(
                "attn_chunks", body * n_scans, trips, 4.0 if train else 1.0))
        return {"model_flops": fwd * mode_factor, "fp4_gemm_flops": fp4 * mode_factor,
                "scan_corrections": scans, "tokens": B * Sdec,
                "layers_fwd_flops": B * (enc_lin * Senc + dec_lin * Sdec + dyn)}

    S_q = 1 if mode == "decode" else S
    S_kv = S
    n_chunk_attn_layers = 0
    for layer in plan:
        kind = layer["kind"]
        if kind == "attn":
            lin_ptok += _attn_linear_ptok(cfg)
            lin_ptok += _moe_ptok(cfg) if layer.get("ffn") == "moe" else \
                _ffn_ptok(cfg)
            w = layer.get("window")
            dyn_pseq += _attn_dynamic(cfg, S_q, S_kv, w)
            if (mode != "decode" and not (w and S_q > w)
                    and S_kv > 2 * cfg.attn_chunk):
                n_chunk_attn_layers += 1
        elif kind == "mla":
            lin_ptok += _mla_linear_ptok(cfg) + _ffn_ptok(cfg)
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            dyn_pseq += _attn_dynamic(cfg, S_q, S_kv, None) * \
                (qk / cfg.resolved_head_dim)
            if mode != "decode" and S_kv > 2 * cfg.attn_chunk:
                n_chunk_attn_layers += 1
        elif kind == "shared_attn":
            lin_ptok += _attn_linear_ptok(cfg) + _ffn_ptok(cfg)
            dyn_pseq += _attn_dynamic(cfg, S_q, S_kv, None)
            if mode != "decode" and S_kv > 2 * cfg.attn_chunk:
                n_chunk_attn_layers += 1
        elif kind == "ssm":
            lin_ptok += _ssm_linear_ptok(cfg)
            if mode == "decode":
                di = cfg.ssm_expand * cfg.d_model
                dyn_pseq += 6.0 * di * cfg.ssm_state
            else:
                dyn_pseq += _ssd_dynamic(cfg, S)
        elif kind == "rwkv":
            lin_ptok += _rwkv_linear_ptok(cfg)
            dyn_pseq += _wkv_dynamic(cfg, S_q if mode == "decode" else S)

    head_ptok = 2.0 * cfg.d_model * cfg.vocab_size
    tokens = B * S_q
    fwd = tokens * (lin_ptok + head_ptok) + B * dyn_pseq
    fp4 = tokens * lin_ptok  # head stays bf16 (policy.quantize_head=False)

    # --- scan corrections -------------------------------------------------
    if n_chunk_attn_layers and mode != "decode":
        trips = -(-S_kv // cfg.attn_chunk)
        body = 4.0 * B * cfg.n_heads * cfg.resolved_head_dim * S_q * \
            cfg.attn_chunk
        scans.append(ScanCorrection("attn_chunks",
                                    body * n_chunk_attn_layers, trips,
                                    4.0 if train else 1.0))
    n_ssm = sum(1 for l in plan if l["kind"] == "ssm")
    if n_ssm and mode != "decode":
        L = min(cfg.ssm_chunk, S)
        trips = max(1, S // L)
        body = B * _ssd_dynamic(cfg, L)
        scans.append(ScanCorrection("ssd_chunks", body * n_ssm, trips,
                                    4.0 if train else 1.0))
    n_rwkv = sum(1 for l in plan if l["kind"] == "rwkv")
    if n_rwkv and mode != "decode":
        body = B * 4.0 * cfg.d_model * cfg.ssm_head_dim
        scans.append(ScanCorrection("wkv_steps", body * n_rwkv, S,
                                    4.0 if train else 1.0))
    # loss chunking is unrolled for <=16 chunks (exact); larger S in train
    # would scan -- train_4k uses 4096/512 = 8 chunks (unrolled).

    return {"model_flops": fwd * mode_factor,
            "fp4_gemm_flops": fp4 * mode_factor,
            "scan_corrections": scans, "tokens": tokens,
            "layers_fwd_flops": tokens * lin_ptok + B * dyn_pseq}


def param_count(params) -> int:
    import jax
    return sum(p.size for p in jax.tree.leaves(params))
