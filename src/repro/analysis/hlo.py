"""Optimized-HLO text analysis: per-device collective wire bytes.

`collective_bytes(hlo_text)` parses every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, computes ring-algorithm
wire bytes from the operand shape and replica-group size, and multiplies
collectives inside `while` bodies by the loop trip count (parsed from the
loop condition's comparison constant).

Trip-count parsing is a heuristic (standard XLA counted-loop pattern:
`compare(gte, constant(N)), direction=LT`); every multiplied entry is
flagged in the returned breakdown so EXPERIMENTS.md can show raw vs
corrected numbers.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# computation header: name, arbitrary (possibly nested) signature, '->', '{'
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONST_CMP_RE = re.compile(r"compare\([^)]*\).*direction=LT")
_CONST_RE = re.compile(r"=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of the first shape in a string like 'f32[8,128]{1,0}'.
    For tuple shapes '(f32[..], u32[..])' sums components."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[num_groups, group_size]<=[total]
        return int(m.group(2))
    return default


def _wire_factor(op: str, n: int) -> float:
    """Ring-algorithm wire bytes per device as a multiple of payload bytes."""
    if op == "collective-permute":
        return 1.0  # point-to-point: group size is not meaningful
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all",
              "ragged-all-to-all"):
        return (n - 1) / n
    if op == "collective-permute":
        return 1.0
    return 1.0


@dataclasses.dataclass
class CollectiveEntry:
    op: str
    payload_bytes: int
    wire_bytes: float
    group_size: int
    computation: str
    multiplier: int  # while-body trip count product (1 = top level)
    line_no: int


def _split_computations(text: str) -> dict[str, list[tuple[int, str]]]:
    comps: dict[str, list[tuple[int, str]]] = {}
    current = None
    for i, line in enumerate(text.splitlines()):
        stripped = line.strip()
        m = _COMP_START_RE.match(stripped)
        if m and stripped.endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is not None:
            comps[current].append((i, stripped))
    return comps


def _find_trip_count(cond_lines: list[tuple[int, str]]) -> int | None:
    """Counted-loop pattern: the comparison constant in the condition."""
    consts = {}
    for _, l in cond_lines:
        m = re.match(r"%?([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)", l)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for _, l in cond_lines:
        if "compare(" in l and "direction=LT" in l:
            # operands of compare
            m = re.search(r"compare\(%?([\w\.\-]+),\s*%?([\w\.\-]+)\)", l)
            if m:
                for name in m.groups():
                    if name in consts:
                        return consts[name]
    # fallback: any integer constant (flagged by caller)
    if consts:
        return max(consts.values())
    return None


def collective_bytes(hlo_text: str) -> dict:
    comps = _split_computations(hlo_text)

    # while ops: map body computation -> trip count
    body_trips: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    for cname, lines in comps.items():
        for _, l in lines:
            m = _WHILE_RE.search(l)
            if m:
                cond, body = m.group(1), m.group(2)
                cond_of_body[body] = cond
                trips = _find_trip_count(comps.get(cond, []))
                body_trips[body] = trips if trips is not None else 1

    # nested whiles: body computations containing while ops multiply
    def multiplier_of(comp: str, seen=()) -> int:
        mult = 1
        # find enclosing bodies: is `comp` a while body?
        if comp in body_trips:
            mult *= max(1, body_trips[comp])
        return mult

    # build parent chain: computation -> enclosing body multiplier. We only
    # handle one nesting level of interest (layer scans); deeper nesting
    # multiplies conservatively by each enclosing body found via call sites.
    calls: dict[str, set[str]] = defaultdict(set)  # callee -> callers
    for cname, lines in comps.items():
        for _, l in lines:
            m = _WHILE_RE.search(l)
            if m:
                calls[m.group(2)].add(cname)

    def full_multiplier(comp: str, depth=0) -> int:
        if depth > 8:
            return 1
        mult = multiplier_of(comp)
        for caller in calls.get(comp, ()):  # enclosing computations
            mult *= full_multiplier(caller, depth + 1)
        return mult

    entries: list[CollectiveEntry] = []
    for cname, lines in comps.items():
        cmult = full_multiplier(cname)
        for line_no, l in lines:
            for op in _COLLECTIVES:
                # match '<shape> op(' and async '-start' forms; skip -done
                if re.search(rf"=\s*[^=]*\b{op}(?:-start)?\(", l) and \
                        f"{op}-done" not in l:
                    lhs = l.split("=", 1)[1]
                    payload = _shape_bytes(lhs.split(f"{op}")[0])
                    n = _group_size(l)
                    entries.append(CollectiveEntry(
                        op=op, payload_bytes=payload,
                        wire_bytes=payload * _wire_factor(op, n),
                        group_size=n, computation=cname,
                        multiplier=cmult, line_no=line_no))
                    break

    by_op: dict[str, float] = defaultdict(float)
    by_op_raw: dict[str, float] = defaultdict(float)
    for e in entries:
        by_op[e.op] += e.wire_bytes * e.multiplier
        by_op_raw[e.op] += e.wire_bytes
    total = sum(by_op.values())
    total_raw = sum(by_op_raw.values())
    return {
        "total_wire_bytes": total,
        "total_wire_bytes_raw": total_raw,
        "by_op": dict(by_op),
        "count": len(entries),
        "multiplied_entries": sum(1 for e in entries if e.multiplier > 1),
        "while_trip_counts": body_trips,
    }
