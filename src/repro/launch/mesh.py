"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing jax.

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips; `pod` is the outer
             data-parallel axis crossing the inter-pod (DCI) links -- the
             hop where fp8 gradient compression applies (dist/grad_comm.py).
"""
from __future__ import annotations

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return compat.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """All batch-parallel axes present in the mesh ('pod' is outer DP).

    Single source of truth lives in the distribution layer.
    """
    from repro.dist.sharding import data_axes as _data_axes
    return _data_axes(mesh)
