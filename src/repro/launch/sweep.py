"""Dry-run sweep driver: every (assigned arch x applicable shape x mesh)
cell as a subprocess (each cell needs a fresh jax with 512 fake devices),
writing JSON artifacts consumed by the roofline report.

Single-core host: cells run serially; `--resume` skips cells whose artifact
already exists, so the sweep is restartable.

    PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun \
        [--mesh single multi] [--archs a b c] [--resume]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config


def cells(archs, meshes, shapes=None):
    shapes = shapes or ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            for mesh in meshes:
                yield arch, shape, mesh, shape in cfg.applicable_shapes()


def artifact_path(out, arch, shape, mesh):
    return os.path.join(out, f"{arch}__{shape}__{mesh}.json")


def run_one(arch, shape, mesh, out, timeout=3600):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", out]
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.dirname(os.path.dirname(__file__)))))
    dt = time.time() - t0
    ok = proc.returncode == 0
    return ok, dt, (proc.stdout + proc.stderr)[-2000:]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--shapes", nargs="+", default=None)
    ap.add_argument("--archs", nargs="+", default=ASSIGNED_ARCHS)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(args.out, "sweep_log.jsonl")
    results = []
    for arch, shape, mesh, applicable in cells(args.archs, args.mesh,
                                               args.shapes):
        path = artifact_path(args.out, arch, shape, mesh)
        if not applicable:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "skipped": True,
                           "reason": "long_500k skipped for pure "
                                     "full-attention arch (DESIGN.md §5)"}, f)
            print(f"SKIP  {arch:24s} {shape:12s} {mesh}")
            continue
        if args.resume and os.path.exists(path):
            print(f"HAVE  {arch:24s} {shape:12s} {mesh}")
            continue
        ok, dt, tail = run_one(arch, shape, mesh, args.out, args.timeout)
        status = "OK " if ok else "FAIL"
        print(f"{status}  {arch:24s} {shape:12s} {mesh}  {dt:6.1f}s",
              flush=True)
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": ok,
               "wall_s": dt}
        if not ok:
            rec["tail"] = tail
        results.append(rec)
        with open(log_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    n_fail = sum(1 for r in results if not r.get("ok", True))
    print(f"done: {len(results)} ran, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
