import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512"
                           " --xla_llvm_disable_expensive_passes=true")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory/cost/collective
analysis + analytic roofline terms as a JSON artifact.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init. Smoke tests and benchmarks never import this
module (they see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen1.5-32b --shape train_4k --mesh single \
        --out artifacts/dryrun [--policy fp4] [--hier]
"""
import argparse
import dataclasses
import json
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import flops as flops_mod
from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as roof_mod
from repro.configs import SHAPES, get_config
from repro.core.policy import get_policy
from repro.dist import compat, sharding as shard_rules
from repro.launch import inputs as inputs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adam as adam_mod
from repro.train import train_step as ts_mod


def _tune_config(cfg, shape):
    """Per-shape execution knobs (documented in DESIGN.md §6)."""
    cfg = cfg.replace(scan_layers=True)  # compile O(group), not O(L)
    if shape.kind == "train":
        # dense attention at 4K: exact FLOP counting, scores fit with remat
        cfg = cfg.replace(attn_chunk=max(cfg.attn_chunk, shape.seq_len))
    else:
        cfg = cfg.replace(attn_chunk=1024)
    if shape.kind == "decode":
        # production decode cells use fp8 KV cache (DESIGN.md §4)
        cfg = cfg.replace(cache_dtype="float8_e4m3fn")
    return cfg


def _eval_shape_with_axes(fn, *args):
    """eval_shape capturing the static logical-axes side channel."""
    box = {}

    def wrapper(*a):
        out, axes = fn(*a)
        box["axes"] = axes
        return out

    struct = jax.eval_shape(wrapper, *args)
    return struct, box["axes"]


def _batch_shardings(batch_struct, mesh):
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)

    def one(x):
        b = b_ax if x.shape[0] % dp == 0 else None
        return NamedSharding(mesh, P(b, *([None] * (x.ndim - 1))))

    return jax.tree.map(one, batch_struct)


def run_cell(arch: str, shape_name: str, mesh_kind: str, policy_name: str,
             hier: bool = False, seq_parallel: bool = True,
             out_dir: str | None = None, save_hlo: bool = False,
             microbatch: int = 0, overrides: dict | None = None,
             tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    cfg = _tune_config(get_config(arch), shape)
    if overrides:
        cfg = cfg.replace(**overrides)
    if shape_name not in cfg.applicable_shapes():
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": True,
                "reason": "long_500k skipped for pure full-attention arch "
                          "(DESIGN.md §5)"}
    policy = get_policy(policy_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    model = build_model(cfg, policy,
                        shard_rules.make_act_constraint(
                            mesh, seq_parallel=seq_parallel))

    if shape.kind == "train" and not microbatch:
        # default microbatching: keep local activation footprint in check
        # (2 local sequences per microbatch; DESIGN.md §4)
        dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        local_b = max(1, shape.global_batch // dp)
        microbatch = max(1, min(8, local_b // 2))

    t0 = time.time()
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            lowered, mode = _lower_train(model, cfg, shape, mesh, hier,
                                         microbatch), "train"
        elif shape.kind == "prefill":
            lowered, mode = _lower_prefill(model, cfg, shape, mesh), "prefill"
        else:
            lowered, mode = _lower_decode(model, cfg, shape, mesh), "decode"
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = compat.cost_analysis(compiled)
    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    colls = hlo_mod.collective_bytes(hlo_text)

    analytic = flops_mod.model_flops(cfg, shape, mode)
    inner_corr = sum(s.correction for s in analytic["scan_corrections"])
    # layer-stack scan correction: the while body holds one group of layers;
    # add the other (n_groups-1) groups analytically (DESIGN.md §6).
    n_groups = getattr(model, "n_groups", 0)
    if cfg.enc_layers and getattr(model, "stacked", False):
        n_groups = min(cfg.enc_layers, cfg.n_layers)
    if n_groups >= 2:
        mult = 4.0 if mode == "train" else 1.0   # fwd + remat + 2x bwd
        stack_corr = analytic["layers_fwd_flops"] * (1 - 1 / n_groups) * mult
        inner_corr = inner_corr / n_groups       # inner scans: counted body only
    else:
        stack_corr = 0.0
    corrections = inner_corr + stack_corr
    hlo_flops_dev = float(ca.get("flops", 0.0))
    corrected_dev = hlo_flops_dev + corrections / n_chips
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    wire_dev = colls["total_wire_bytes"]
    fp4_frac = (analytic["fp4_gemm_flops"] / analytic["model_flops"]
                if analytic["model_flops"] else 0.0)

    roof = roof_mod.roofline_terms(
        hlo_flops_per_dev=hlo_flops_dev,
        corrected_flops_per_dev=corrected_dev,
        hbm_bytes_per_dev=bytes_dev,
        wire_bytes_per_dev=wire_dev,
        fp4_fraction=fp4_frac)

    model_flops_dev = analytic["model_flops"] / n_chips
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "policy": policy_name, "hier": hier, "skipped": False,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {
            "flops_per_dev": hlo_flops_dev,
            "bytes_accessed_per_dev": bytes_dev,
            "transcendentals_per_dev": float(ca.get("transcendentals", 0.0)),
        },
        "memory_analysis": {
            "argument_bytes_per_dev": ma.argument_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "alias_bytes_per_dev": ma.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                 ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9, 3),
        },
        "collectives": colls,
        "analytic": {
            "model_flops_global": analytic["model_flops"],
            "fp4_gemm_flops_global": analytic["fp4_gemm_flops"],
            "fp4_fraction": fp4_frac,
            "n_layer_groups": n_groups,
            "stack_correction_global": stack_corr,
            "scan_corrections_global": corrections,
            "scan_detail": [dataclasses.asdict(s) | {"correction": s.correction}
                            for s in analytic["scan_corrections"]],
            "tokens": analytic["tokens"],
        },
        "flops": {
            "hlo_per_dev": hlo_flops_dev,
            "corrected_per_dev": corrected_dev,
            "model_per_dev": model_flops_dev,
            "useful_ratio": (model_flops_dev / corrected_dev
                             if corrected_dev else 0.0),
        },
        "roofline": roof.as_dict(),
        "mfu_bound": roof_mod.mfu(model_flops_dev, roof.step_time_s),
        "hw_util_bound": roof_mod.hw_utilization(
            corrected_dev, roof.step_time_s, fp4_frac),
    }
    result["tag"] = tag
    result["overrides"] = overrides or {}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}"
                            + (f"__{policy_name}" if policy_name != "fp4" else "")
                            + (f"__{tag}" if tag else "")
                            + ("__hier" if hier else "") + ".json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo_text)
    return result


def _lower_train(model, cfg, shape, mesh, hier, microbatch=1):
    adam_cfg = adam_mod.AdamConfig()
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state_struct, axes = _eval_shape_with_axes(
        lambda k: _init_state_with_axes(model, adam_cfg, k), key_struct)
    shardings = ts_mod.state_shardings(state_struct, axes, mesh)
    batch = inputs_mod.batch_struct(cfg, shape.seq_len, shape.global_batch)
    bshard = _batch_shardings(batch, mesh)
    if hier and "pod" in mesh.axis_names:
        step = ts_mod.make_hier_train_step(model, mesh)
    else:
        step = ts_mod.make_train_step(model, mesh, microbatch=microbatch)
    fn = jax.jit(step, in_shardings=(shardings, bshard), donate_argnums=0)
    return fn.lower(state_struct, batch)


def _init_state_with_axes(model, adam_cfg, key):
    state, axes = ts_mod.init_state(model, adam_cfg, key)
    return state, axes


def _bf16_params(struct):
    """Serving params are bf16 (checkpoint export precision)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, struct)


def _lower_prefill(model, cfg, shape, mesh):
    B, S = shape.global_batch, shape.seq_len
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_struct, axes = _eval_shape_with_axes(
        lambda k: model.init(k), key_struct)
    params_struct = _bf16_params(params_struct)
    pshard = shard_rules.param_shardings(axes, params_struct, mesh)
    batch = inputs_mod.batch_struct(cfg, S, B)
    bshard = _batch_shardings(batch, mesh)
    if cfg.enc_layers:
        cache_struct = jax.eval_shape(
            partial(model.init_cache, B, S // 2, memory_len=S // 2))
    else:
        cache_struct = jax.eval_shape(partial(model.init_cache, B, S))
    cshard = shard_rules.cache_shardings(cache_struct, mesh)
    fn = jax.jit(model.prefill,
                 in_shardings=(pshard, bshard, cshard),
                 donate_argnums=2)
    return fn.lower(params_struct, batch, cache_struct)


def _lower_decode(model, cfg, shape, mesh):
    B, S = shape.global_batch, shape.seq_len
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_struct, axes = _eval_shape_with_axes(
        lambda k: model.init(k), key_struct)
    params_struct = _bf16_params(params_struct)
    pshard = shard_rules.param_shardings(axes, params_struct, mesh)
    if cfg.enc_layers:
        cache_struct = jax.eval_shape(
            partial(model.init_cache, B, S, memory_len=S // 2))
    else:
        cache_struct = jax.eval_shape(partial(model.init_cache, B, S))
    cshard = shard_rules.cache_shardings(cache_struct, mesh)
    tok_struct, pos_struct = inputs_mod.decode_struct(cfg, B)
    tshard = _batch_shardings({"t": tok_struct}, mesh)["t"]
    posshard = NamedSharding(mesh, P())
    fn = jax.jit(model.decode_step,
                 in_shardings=(pshard, cshard, tshard, posshard),
                 donate_argnums=1)
    return fn.lower(params_struct, cache_struct, tok_struct,
                    jax.ShapeDtypeStruct((), jnp.int32))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--policy", default="fp4")
    ap.add_argument("--hier", action="store_true",
                    help="multi-pod hierarchical fp8 grad-comm train step")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--override", nargs="*", default=[],
                    help="config overrides k=v (int/bool/str inferred)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("true", "false"):
            overrides[k] = v == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    res = run_cell(args.arch, args.shape, args.mesh, args.policy,
                   hier=args.hier, seq_parallel=not args.no_seq_parallel,
                   out_dir=args.out, save_hlo=args.save_hlo,
                   overrides=overrides, tag=args.tag)
    if res.get("skipped"):
        print(f"SKIP {args.arch} {args.shape} {args.mesh}: {res['reason']}")
        return
    print(json.dumps({k: res[k] for k in
                      ("arch", "shape", "mesh", "compile_s", "roofline",
                       "mfu_bound")}, indent=1))
    ma = res["memory_analysis"]
    print(f"memory/device: args {ma['argument_bytes_per_dev']/1e9:.2f} GB, "
          f"temps {ma['temp_bytes_per_dev']/1e9:.2f} GB, "
          f"peak~{ma['peak_estimate_gb']:.2f} GB")
    print(f"collectives: {res['collectives']['total_wire_bytes']/1e9:.3f} GB/dev wire, "
          f"{res['collectives']['count']} ops")
    print(f"flops/dev: hlo {res['flops']['hlo_per_dev']:.3e} "
          f"corrected {res['flops']['corrected_per_dev']:.3e} "
          f"model {res['flops']['model_per_dev']:.3e} "
          f"useful_ratio {res['flops']['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
