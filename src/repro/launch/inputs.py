"""Batch layouts per architecture family: concrete batches (tests/training)
and ShapeDtypeStruct stand-ins (dry-run lowering, no allocation).

Family layouts:
  tokens      -> {"tokens": (B, S) i32}
  embeddings  -> {"embeds": (B, S, D) bf16, "labels": (B, S) i32}   (vlm/audio
                 frontends are stubs per the assignment)
  encdec      -> {"enc_embeds": (B, S/2, D) bf16, "tokens": (B, S/2) i32}
                 (seq_len counts total positions across enc+dec, DESIGN §9)

Decode-step inputs: tokens (B, 1) i32 (embeds (B,1,D) pre-prefill for stub
frontends decode text tokens), position scalar i32, plus the KV cache pytree
built by the model's init_cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec


def _is_encdec(cfg: ArchConfig) -> bool:
    return cfg.enc_layers > 0


def batch_struct(cfg: ArchConfig, seq_len: int, batch: int):
    """ShapeDtypeStructs for one training/prefill batch."""
    bf16 = jnp.bfloat16
    if _is_encdec(cfg):
        half = seq_len // 2
        return {
            "enc_embeds": jax.ShapeDtypeStruct((batch, half, cfg.d_model), bf16),
            "tokens": jax.ShapeDtypeStruct((batch, half), jnp.int32),
        }
    if cfg.frontend == "embeddings":
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model), bf16),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}


def decode_struct(cfg: ArchConfig, batch: int):
    return (jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))


def make_batch(cfg: ArchConfig, seq_len: int, batch: int, seed: int = 0):
    """Concrete random batch matching batch_struct."""
    rng = np.random.default_rng(seed)
    if _is_encdec(cfg):
        half = seq_len // 2
        return {
            "enc_embeds": jnp.asarray(
                rng.normal(size=(batch, half, cfg.d_model)), jnp.bfloat16),
            "tokens": jnp.asarray(
                rng.integers(1, cfg.vocab_size, (batch, half)), jnp.int32),
        }
    if cfg.frontend == "embeddings":
        return {
            "embeds": jnp.asarray(
                rng.normal(size=(batch, seq_len, cfg.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(1, cfg.vocab_size, (batch, seq_len)), jnp.int32),
        }
    return {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (batch, seq_len)), jnp.int32)}
