"""Seeded fault-injection scenarios and their recovery invariants.

Each scenario is a function `fn(ctx)` registered with `@scenario(*tags)`.
It composes injectors from `chaos.inject` with a short real train/serve
session (tiny recording step functions -- no jit needed for the fast
set), then records invariant checks on the `ctx`:

  * a run killed mid-checkpoint and resumed consumes a token stream
    identical to an uninterrupted run (the headline invariant),
  * no `.tmp` / `.old.<pid>` debris survives recovery,
  * a sentinel trip checkpoints and flips to the bf16 fallback step,
  * corrupted artifacts (checkpoints, shard manifests, autotune caches)
    are rejected or skipped with clean errors, never half-loaded,
  * a wedged prefetch producer surfaces as a timeout and is fenced off
    by `restart`, never leaking a stale batch.

Scenarios are deterministic: every random choice comes from a
`np.random.default_rng` seeded with (run seed, scenario name), so
`python -m repro.chaos --scenarios fast --seed 0` replays exactly.
Tags select subsets: "fast" runs in seconds with no model compilation;
"full" adds subprocess SIGKILL-style kills and a real-model serve
scenario.  `hooks.clear()` runs between scenarios so no handler leaks.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import subprocess
import sys
import tempfile
import threading
import time
import traceback
import warnings
import zlib
from typing import Callable

import numpy as np

from . import hooks, inject

_REGISTRY: dict[str, tuple[Callable, frozenset]] = {}
_FINAL_RE = re.compile(r"step_\d+$")


def scenario(*tags: str):
    """Register a scenario under its function name with the given tags."""
    tagset = frozenset(tags) | {"all"}

    def deco(fn):
        _REGISTRY[fn.__name__] = (fn, tagset)
        return fn
    return deco


def names(selector: str = "fast") -> list[str]:
    """Scenario names matching a selector: tag(s) and/or explicit names.

    "fast" -> the quick set, "full"/"all" -> everything, or a comma list
    mixing tags and scenario names ("ckpt,prefetch_stall_restart").
    """
    wanted = {t.strip() for t in selector.split(",") if t.strip()}
    if "full" in wanted:
        wanted.add("all")
    out = []
    for name, (_, tags) in _REGISTRY.items():
        if name in wanted or (wanted & tags):
            out.append(name)
    unknown = wanted - set(_REGISTRY) - {t for _, ts in _REGISTRY.values()
                                         for t in ts}
    if unknown:
        raise ValueError(f"unknown scenario/tag selector(s): "
                         f"{sorted(unknown)}")
    return out


@dataclasses.dataclass
class Check:
    name: str
    ok: bool
    detail: str = ""


@dataclasses.dataclass
class ScenarioResult:
    name: str
    seed: int
    ok: bool
    seconds: float
    checks: list[Check]
    error: str | None = None

    def to_record(self) -> dict:
        return {"scenario": self.name, "seed": self.seed, "ok": self.ok,
                "seconds": round(self.seconds, 3),
                "checks": [dataclasses.asdict(c) for c in self.checks],
                "error": self.error}


class Ctx:
    """Per-scenario context: seeded rng, scratch dir, invariant checks."""

    def __init__(self, name: str, seed: int, workdir: str):
        self.name = name
        self.seed = seed
        self.workdir = workdir
        self.rng = np.random.default_rng(
            [seed, zlib.crc32(name.encode()) & 0x7FFFFFFF])
        self.checks: list[Check] = []

    def subdir(self, name: str) -> str:
        d = os.path.join(self.workdir, name)
        os.makedirs(d, exist_ok=True)
        return d

    def check(self, name: str, ok, detail: str = "") -> bool:
        self.checks.append(Check(name, bool(ok), detail))
        return bool(ok)

    def expect_crash(self, name: str, fn: Callable) -> None:
        """Run `fn`; the installed crash handler must fire."""
        try:
            fn()
        except hooks.SimulatedCrash:
            self.check(name, True)
        else:
            self.check(name, False, "SimulatedCrash did not fire")


# --------------------------------------------------------------------------
# shared builders (tiny recording train runs -- no jit, all host numpy)
# --------------------------------------------------------------------------

def _build_corpus(root: str, rng, n_docs: int = 32, vocab: int = 97,
                  shard_tokens: int = 256) -> str:
    from repro.data.shards import ShardWriter
    w = ShardWriter(root, vocab_size=vocab, shard_tokens=shard_tokens)
    for _ in range(n_docs):
        w.add_document(rng.integers(1, vocab,
                                    size=int(rng.integers(4, 40))))
    return w.finalize()


def _stream(manifest: str, seed: int = 0, seq_len: int = 32,
            batch_size: int = 2):
    from repro.data.shards import ShardReader
    from repro.data.stream import PackedStream
    return PackedStream(ShardReader(manifest), seq_len=seq_len,
                        batch_size=batch_size, seed=seed)


def _recording_trainer(loader, ckpt_dir, total: int, record: list,
                       ckpt_every: int = 4, **cfg_kw):
    """Trainer whose step_fn records (step, tokens) -- the token stream
    IS the thing the crash/resume invariants compare."""
    from repro.train.trainer import Trainer, TrainerConfig

    def step_fn(state, batch):
        s = int(state["step"])
        record.append((s, np.asarray(batch["tokens"]).copy()))
        return {"step": np.int32(s + 1)}, {"loss": np.float32(1.0)}

    cfg = TrainerConfig(total_steps=total, ckpt_dir=ckpt_dir,
                        ckpt_every=ckpt_every, log_every=10_000, **cfg_kw)
    return Trainer(step_fn, {"step": np.int32(0)}, loader=loader, cfg=cfg)


def _reference_tokens(manifest: str, total: int) -> dict:
    """step -> tokens of an uninterrupted run (the ground truth)."""
    rec: list = []
    _recording_trainer(_stream(manifest), None, total, rec).run(resume=False)
    return dict(rec)


def _records_match(ctx: Ctx, label: str, records: list, ref: dict) -> None:
    for s, toks in records:
        if s not in ref or not np.array_equal(toks, ref[s]):
            ctx.check(f"{label}: token-identical to uninterrupted run",
                      False, f"step {s} diverged")
            return
    ctx.check(f"{label}: token-identical to uninterrupted run", True,
              f"{len(records)} steps compared")


def _debris(root: str) -> list[str]:
    if not os.path.isdir(root):
        return []
    return [n for n in os.listdir(root)
            if n.endswith(".tmp") or ".old." in n]


def _final_dirs(root: str) -> list[str]:
    return sorted(n for n in os.listdir(root) if _FINAL_RE.fullmatch(n))


# --------------------------------------------------------------------------
# checkpoint crash-consistency
# --------------------------------------------------------------------------

@scenario("fast", "ckpt")
def kill_mid_checkpoint_resume(ctx: Ctx):
    """SIGKILL during the checkpoint commit rename; the resumed run must
    be token-identical to an uninterrupted one (the headline invariant)."""
    manifest = _build_corpus(ctx.subdir("corpus"), ctx.rng)
    total = 12
    ref = _reference_tokens(manifest, total)
    ckpt = ctx.subdir("ckpt")
    rec1: list = []
    tr = _recording_trainer(_stream(manifest), ckpt, total, rec1)
    with hooks.installed("ckpt.pre_rename", hooks.crash_handler(nth=2)):
        ctx.expect_crash("crash during 2nd checkpoint commit",
                         lambda: tr.run(resume=False))
    rec2: list = []
    tr2 = _recording_trainer(_stream(manifest), ckpt, total, rec2)
    tr2.run(resume=True)
    ctx.check("resumed from the surviving checkpoint",
              0 < tr2.start_step < total, f"start_step={tr2.start_step}")
    _records_match(ctx, "pre-crash run", rec1, ref)
    _records_match(ctx, "resumed run", rec2, ref)
    covered = {s for s, _ in rec1} | {s for s, _ in rec2}
    ctx.check("every step covered across crash+resume",
              covered == set(range(total)), f"covered={sorted(covered)}")
    ctx.check("final step reached", int(tr2.state["step"]) == total)
    ctx.check("no debris after resume", not _debris(ckpt),
              repr(_debris(ckpt)))


@scenario("fast", "ckpt")
def kill_mid_checkpoint_write(ctx: Ctx):
    """SIGKILL while the checkpoint tmp dir is half-written: the debris
    must never be mistaken for a checkpoint, and resume still works."""
    manifest = _build_corpus(ctx.subdir("corpus"), ctx.rng)
    total = 12
    ref = _reference_tokens(manifest, total)
    ckpt = ctx.subdir("ckpt")
    rec1: list = []
    tr = _recording_trainer(_stream(manifest), ckpt, total, rec1)
    with hooks.installed("ckpt.pre_manifest", hooks.crash_handler(nth=2)):
        ctx.expect_crash("crash mid-checkpoint-write",
                         lambda: tr.run(resume=False))
    ctx.check("half-written .tmp debris left by the kill",
              any(n.endswith(".tmp") for n in os.listdir(ckpt)),
              repr(os.listdir(ckpt)))
    rec2: list = []
    tr2 = _recording_trainer(_stream(manifest), ckpt, total, rec2)
    tr2.run(resume=True)
    ctx.check("resumed from the last COMPLETE checkpoint",
              0 < tr2.start_step < total, f"start_step={tr2.start_step}")
    _records_match(ctx, "resumed run", rec2, ref)
    covered = {s for s, _ in rec1} | {s for s, _ in rec2}
    ctx.check("every step covered across crash+resume",
              covered == set(range(total)))
    ctx.check("tmp debris cleaned on resume",
              not any(n.endswith(".tmp") for n in os.listdir(ckpt)))


@scenario("fast", "ckpt")
def checkpoint_resave_crash_windows(ctx: Ctx):
    """Re-saving over an existing step dir must be atomic in every crash
    window: park-old -> rename-new -> cleanup (DESIGN.md §15)."""
    from repro.train import checkpoint as ck
    root = ctx.subdir("ckpt")

    def st(v):
        return {"w": np.full((4,), float(v), np.float32),
                "step": np.int32(5)}

    ck.save(root, 5, st(1))
    ck.save(root, 5, st(2))
    state, _ = ck.restore(root, st(0))
    ctx.check("re-save atomically replaced the payload",
              float(state["w"][0]) == 2.0)
    ctx.check("no debris after clean re-save", not _debris(root))
    with hooks.installed("ckpt.post_rename", hooks.crash_handler()):
        ctx.expect_crash("crash after commit, before old-dir cleanup",
                         lambda: ck.save(root, 5, st(3)))
    ctx.check("parked .old dir left by the kill",
              any(".old." in n for n in os.listdir(root)))
    ctx.check("latest_step sees through the debris",
              ck.latest_step(root) == 5)
    state, _ = ck.restore(root, st(0))
    ctx.check("restore returns the committed new payload",
              float(state["w"][0]) == 3.0)
    ctx.check("parked debris cleaned", not _debris(root))
    # the other crash window: killed between park and commit -- only the
    # parked old dir exists.  Recovery must roll it back, not lose step 5.
    final = _final_dirs(root)[0]
    os.rename(os.path.join(root, final),
              os.path.join(root, final + ".old.99999"))
    ctx.check("parked-only step is recovered", ck.latest_step(root) == 5)
    state, _ = ck.restore(root, st(0))
    ctx.check("rolled-back payload intact", float(state["w"][0]) == 3.0)
    ctx.check("no debris after rollback", not _debris(root))


@scenario("fast", "ckpt", "corruption")
def checkpoint_corruption_fallback(ctx: Ctx):
    """Byte-corrupted checkpoints are skipped (newest-first scan falls
    back to an older intact one) or rejected with CheckpointError --
    never silently half-restored."""
    from repro.train import checkpoint as ck
    root = ctx.subdir("ckpt")

    def st(v):
        return {"w": np.full((8,), float(v), np.float32),
                "step": np.int32(v)}

    ck.save(root, 2, st(2))
    ck.save(root, 4, st(4))
    npz = os.path.join(root, _final_dirs(root)[-1], "arrays.npz")
    inject.corrupt_bytes(npz, ctx.rng, n_bytes=64)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        state, _ = ck.restore(root, st(0))
    ctx.check("restore fell back to the older intact checkpoint",
              int(state["step"]) == 2, f"step={int(state['step'])}")
    ctx.check("fallback emitted a warning", len(w) >= 1)
    try:
        ck.restore(root, st(0), step=4)
        ctx.check("explicitly requested corrupt step rejected", False)
    except ck.CheckpointError:
        ctx.check("explicitly requested corrupt step rejected", True)
    inject.garbage_file(os.path.join(root, _final_dirs(root)[0],
                                     "manifest.json"))
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ck.restore(root, st(0))
        ctx.check("all-corrupt dir raises CheckpointError", False)
    except ck.CheckpointError:
        ctx.check("all-corrupt dir raises CheckpointError", True)


# --------------------------------------------------------------------------
# shard / data-pipeline faults
# --------------------------------------------------------------------------

@scenario("fast", "data")
def shard_kill_mid_write(ctx: Ctx):
    """SIGKILL mid-shard-write: the manifest-less directory is refused,
    and a rewrite over the rubble yields a byte-exact corpus."""
    from repro.data.shards import ShardReader, ShardWriter
    docs = [ctx.rng.integers(1, 97, size=int(ctx.rng.integers(4, 40)))
            for _ in range(24)]

    def write(root, crash_point=None):
        def go():
            w = ShardWriter(root, vocab_size=97, shard_tokens=128)
            for d in docs:
                w.add_document(d)
            return w.finalize()
        if crash_point is None:
            return go()
        with hooks.installed(crash_point, hooks.crash_handler()):
            ctx.expect_crash(f"crash at {crash_point}", go)
        return None

    write(ctx.subdir("kill_idx"), "shard.pre_idx")
    write(ctx.subdir("kill_manifest"), "shard.pre_manifest")
    for d in ("kill_idx", "kill_manifest"):
        try:
            ShardReader(ctx.subdir(d))
            ctx.check(f"reader refuses manifest-less dir ({d})", False)
        except (FileNotFoundError, ValueError):
            ctx.check(f"reader refuses manifest-less dir ({d})", True)
    manifest = write(ctx.subdir("kill_idx"))
    r = ShardReader(manifest)
    exact = (r.total_docs == len(docs) and
             all(np.array_equal(r.doc(i), docs[i].astype(r.dtype))
                 for i in range(len(docs))))
    ctx.check("rewrite over the rubble is byte-exact", exact)


@scenario("fast", "data", "corruption")
def shard_corruption_rejected(ctx: Ctx):
    """Truncated shard files and garbage manifests raise clean errors
    instead of silently serving short/garbage documents."""
    from repro.data.shards import ShardReader
    m1 = _build_corpus(ctx.subdir("c1"), ctx.rng)
    r = ShardReader(m1)
    inject.truncate_file(os.path.join(r.root, r.shards[0]["file"]), 0.5)
    try:
        ShardReader(m1).doc(0)
        ctx.check("truncated .bin rejected at map time", False)
    except ValueError as e:
        ctx.check("truncated .bin rejected at map time",
                  "truncated or corrupt" in str(e), str(e))
    m2 = _build_corpus(ctx.subdir("c2"), ctx.rng)
    inject.garbage_file(m2)
    try:
        ShardReader(m2)
        ctx.check("garbage manifest rejected with clean error", False)
    except ValueError as e:
        ctx.check("garbage manifest rejected with clean error",
                  "corrupt" in str(e), str(e))


@scenario("fast", "corruption")
def autotune_cache_corruption(ctx: Ctx):
    """A corrupt or foreign-version autotune cache must degrade to the
    heuristic path with a warning, never crash kernel launch."""
    from repro.kernels.autotune import CACHE_VERSION, AutotuneCache
    path = os.path.join(ctx.workdir, "autotune.json")
    cases = {
        "garbage bytes": b"{]] not json",
        "json list top-level": b"[1, 2, 3]",
        "foreign version": json.dumps(
            {"version": 999, "entries": {"x": [64, 64, 64]}}).encode(),
        "malformed entries": json.dumps(
            {"version": CACHE_VERSION,
             "entries": {"a": [1, 2], "b": "?", 3: None}}).encode(),
    }
    for label, payload in cases.items():
        with open(path, "wb") as f:
            f.write(payload)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            cache = AutotuneCache(path)
            val = cache.get("q4gemm", "cpu", 64, 64, 64)
        ctx.check(f"{label}: warned and fell back to empty cache",
                  len(w) >= 1 and val is None,
                  f"warnings={len(w)} get={val!r}")
    cache.put("q4gemm", "cpu", 64, 64, 64, (16, 16, 16))
    reread = AutotuneCache(path).get("q4gemm", "cpu", 64, 64, 64)
    ctx.check("cache rebuilt after corruption round-trips",
              tuple(reread or ()) == (16, 16, 16), repr(reread))


@scenario("fast", "data", "prefetch")
def prefetch_stall_restart(ctx: Ctx):
    """A wedged prefetch producer surfaces as TimeoutError; restart()
    fences it off -- the stale generation can never leak a batch."""
    from repro.data.packing import PackedBatch
    from repro.data.prefetch import DevicePrefetcher

    class GatedStream:
        """Cursor advances before the (gated) slow part of the draw, so
        reseeks aren't clobbered -- the fence is the thing under test."""

        def __init__(self):
            self.i = 0
            self.gate = threading.Event()
            self.gate.set()

        def next_batch(self):
            i = self.i
            self.i = i + 1
            self.gate.wait(20.0)
            return PackedBatch({"tokens": np.full((1, 4), i, np.int32)},
                               {"pack_frac": 1.0})

        def state_dict(self):
            return {"i": self.i}

        def load_state_dict(self, s):
            self.i = int(s["i"])

    stream = GatedStream()
    pf = DevicePrefetcher(stream, depth=1, stall_timeout=0.5,
                          join_timeout=0.2)
    first = pf.next_batch()
    ctx.check("warm prefetcher serves",
              int(first.arrays["tokens"][0, 0]) == 0)
    stream.gate.clear()                    # wedge the producer mid-draw
    try:
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            pf.next_batch()                # drain read-ahead, then stall
        ctx.check("wedged producer surfaces as TimeoutError", False,
                  "never timed out")
    except TimeoutError:
        ctx.check("wedged producer surfaces as TimeoutError", True)
    pf.restart({"i": 100})                 # old producer still wedged
    stream.gate.set()                      # release the zombie
    got = [int(pf.next_batch().arrays["tokens"][0, 0]) for _ in range(4)]
    ctx.check("no stale pre-restart batch leaked past the fence",
              got == [100, 101, 102, 103], repr(got))
    pf.stop()


@scenario("fast", "data", "prefetch")
def prefetch_producer_death(ctx: Ctx):
    """A producer that dies (I/O error) surfaces to the consumer as a
    clean RuntimeError carrying the cause -- not a hang, not silence."""
    from repro.data.packing import PackedBatch
    from repro.data.prefetch import DevicePrefetcher

    class DyingStream:
        def __init__(self):
            self.i = 0

        def next_batch(self):
            if self.i >= 2:
                raise OSError("disk vanished")
            i = self.i
            self.i += 1
            return PackedBatch({"tokens": np.full((1, 4), i, np.int32)},
                               {"pack_frac": 1.0})

        def state_dict(self):
            return {"i": self.i}

        def load_state_dict(self, s):
            self.i = int(s["i"])

    pf = DevicePrefetcher(DyingStream(), depth=1, stall_timeout=2.0)
    served = []
    err = None
    try:
        for _ in range(5):
            served.append(int(pf.next_batch().arrays["tokens"][0, 0]))
    except RuntimeError as e:
        err = e
    ctx.check("good batches served before the death", served == [0, 1],
              repr(served))
    ctx.check("producer death surfaces as RuntimeError with cause",
              err is not None and isinstance(err.__cause__, OSError),
              repr(err))
    pf.stop()


# --------------------------------------------------------------------------
# trainer-level stability faults
# --------------------------------------------------------------------------

@scenario("fast", "trainer")
def nan_burst_skip_budget(ctx: Ctx):
    """A NaN-loss burst within the skip budget is absorbed (updates
    skipped, run completes); a burst past the budget aborts cleanly."""
    from repro.train.trainer import Trainer, TrainerConfig

    def batch_fn(step):
        return {"tokens": np.full((2, 8), step, np.int32)}

    def make(max_skips):
        def step_fn(state, batch):
            s = int(state["step"])
            return {"step": np.int32(s + 1)}, {"loss": np.float32(1.0)}
        cfg = TrainerConfig(total_steps=10, max_nan_skips=max_skips,
                            log_every=10_000)
        return Trainer(step_fn, {"step": np.int32(0)}, batch_fn=batch_fn,
                       cfg=cfg)

    tr = make(5)
    with hooks.installed("trainer.loss", inject.nan_loss_burst({3, 4, 5})):
        hist = tr.run(resume=False)
    skips = [h for h in hist if h.get("event") == "nan_skip"]
    ctx.check("each NaN step skipped the update",
              {h["step"] for h in skips} == {3, 4, 5}, repr(skips))
    ctx.check("run completed within the budget",
              hist[-1]["step"] == 9 and np.isfinite(hist[-1]["loss"]))
    ctx.check("skipped updates were not applied",
              int(tr.state["step"]) == 10 - 3,
              f"state step={int(tr.state['step'])}")
    tr2 = make(2)
    with hooks.installed("trainer.loss",
                         inject.nan_loss_burst(range(3, 9))):
        try:
            tr2.run(resume=False)
            ctx.check("burst past the budget aborts", False)
        except FloatingPointError:
            ctx.check("burst past the budget aborts", True)


@scenario("fast", "trainer", "sentinel")
def sentinel_trip_bf16_fallback(ctx: Ctx):
    """An injected activation-outlier burst trips the collapse sentinel:
    update skipped, checkpoint written, bf16 fallback engaged, and the
    loss recovers on the fallback arm (DESIGN.md §11/§15)."""
    from repro.obs import SentinelConfig
    from repro.train import checkpoint as ck
    from repro.train.trainer import Trainer, TrainerConfig

    healthy_obs = {"agg/min_snr_db": np.float32(14.0),
                   "agg/max_clamp_frac": np.float32(0.01)}

    def primary(state, batch):
        s = int(state["step"])
        return ({"step": np.int32(s + 1)},
                {"loss": np.float32(5.0), "obs": dict(healthy_obs)})

    def fallback(state, batch):
        s = int(state["step"])
        return ({"step": np.int32(s + 1)},
                {"loss": np.float32(1.0), "obs": dict(healthy_obs)})

    ckpt = ctx.subdir("ckpt")
    cfg = TrainerConfig(total_steps=10, ckpt_dir=ckpt, ckpt_every=100,
                        log_every=10_000,
                        sentinel=SentinelConfig(patience=2, warmup_steps=0))
    tr = Trainer(primary, {"step": np.int32(0)},
                 batch_fn=lambda s: {"x": np.zeros((1,), np.float32)},
                 cfg=cfg, fallback_step_fn=fallback)
    with hooks.installed("sentinel.obs",
                         inject.outlier_obs_burst({2, 3})):
        hist = tr.run(resume=False)
    trips = [h for h in hist if h.get("event") == "collapse_trip"]
    fb = [h for h in hist if h.get("event") == "bf16_fallback"]
    ctx.check("sentinel tripped once, after `patience` bad steps",
              len(trips) == 1 and trips[0]["step"] == 3, repr(trips))
    ctx.check("bf16 fallback engaged", len(fb) == 1 and tr.fallback_active)
    saved_steps = [int(n.split("_")[1]) for n in _final_dirs(ckpt)]
    ctx.check("checkpoint written at the trip", 3 in saved_steps,
              repr(saved_steps))
    post = [h["loss"] for h in hist if "loss" in h and h["step"] > 3]
    ctx.check("post-trip steps run the fallback arm (loss recovered)",
              bool(post) and all(l == 1.0 for l in post), repr(post))
    ctx.check("run completed (trip within NaN-skip budget)",
              hist[-1]["step"] == 9)


@scenario("fast", "trainer", "ckpt")
def device_loss_rollback(ctx: Ctx):
    """A step that raises (simulated device loss) rolls back to the last
    checkpoint, reseeks the data stream, and replays token-identically."""
    manifest = _build_corpus(ctx.subdir("corpus"), ctx.rng)
    total = 10
    ref = _reference_tokens(manifest, total)
    rec: list = []
    tr = _recording_trainer(_stream(manifest), ctx.subdir("ckpt"), total,
                            rec, ckpt_every=3)
    tr.fail_injector = inject.fail_step_once(5)
    hist = tr.run(resume=False)
    restored = [h for h in hist if h.get("event") == "restored"]
    ctx.check("retry path restored from checkpoint once",
              len(restored) == 1, repr(restored))
    _records_match(ctx, "rollback replay", rec, ref)
    ctx.check("every step covered despite the rollback",
              {s for s, _ in rec} == set(range(total)))
    ctx.check("final step reached", int(tr.state["step"]) == total)


# --------------------------------------------------------------------------
# full set: subprocess SIGKILL + real-model serve faults
# --------------------------------------------------------------------------

@scenario("full", "subprocess")
def subprocess_kill_resume(ctx: Ctx):
    """A real child process hard-killed (os._exit, SIGKILL-style) mid
    checkpoint commit; rerunning the same command resumes and the merged
    token stream matches an uninterrupted child bit-for-bit."""
    corpus = ctx.subdir("corpus")
    _build_corpus(corpus, ctx.rng)
    total = 12

    def child(ckpt, out, extra_env=None):
        env = dict(os.environ,
                   PYTHONPATH=os.pathsep.join(
                       [p for p in (_src_path(),
                                    os.environ.get("PYTHONPATH")) if p]))
        env.update(extra_env or {})
        return subprocess.run(
            [sys.executable, "-m", "repro.chaos._child",
             "--corpus", corpus, "--ckpt", ckpt,
             "--total", str(total), "--out", out],
            env=env, capture_output=True, text=True, timeout=600)

    ref_out = os.path.join(ctx.workdir, "ref.json")
    p = child(ctx.subdir("ckpt_ref"), ref_out)
    ctx.check("reference child ran clean", p.returncode == 0,
              p.stderr[-500:])
    ckpt = ctx.subdir("ckpt")
    out = os.path.join(ctx.workdir, "resumed.json")
    p1 = child(ckpt, out, hooks.kill_env("ckpt.pre_rename", nth=2))
    ctx.check("child hard-killed mid-commit (exit 137)",
              p1.returncode == hooks.KILL_EXIT_CODE,
              f"rc={p1.returncode} {p1.stderr[-300:]}")
    ctx.check("killed child wrote no result", not os.path.exists(out))
    p2 = child(ckpt, out)
    ctx.check("resumed child ran clean", p2.returncode == 0,
              p2.stderr[-500:])
    if p.returncode == 0 and p2.returncode == 0:
        ref = {r["step"]: r["crc"] for r in json.load(open(ref_out))}
        res = json.load(open(out))
        ctx.check("resume started mid-run",
                  0 < min(r["step"] for r in res) < total)
        ctx.check("resumed stream token-identical to uninterrupted child",
                  all(ref.get(r["step"]) == r["crc"] for r in res),
                  f"{len(res)} steps compared")
        ctx.check("resumed child reached the final step",
                  max(r["step"] for r in res) == total - 1)
    ctx.check("no debris after resume", not _debris(ckpt),
              repr(_debris(ckpt)))


@scenario("full", "serve")
def serve_cancel_storm(ctx: Ctx):
    """Seeded cancels injected mid-decode via the serve.pre_step seam:
    the engine must drain, free every page, and finish every
    non-cancelled request (DESIGN.md §13/§15)."""
    import jax
    from repro.configs import get_config
    from repro.core.policy import BF16
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    cfg = get_config("llama2-400m", smoke=True).replace(
        cache_dtype="float32", remat=False)
    model = build_model(cfg, BF16.replace(compute="float32"))
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, n_slots=4, max_len=48,
                      prefill_len=16, page_size=4)
    free0 = eng.allocator.available
    prompts = [ctx.rng.integers(1, cfg.vocab_size,
                                size=int(ctx.rng.integers(3, 14))).tolist()
               for _ in range(6)]
    rids = [eng.submit(p, 8) for p in prompts]
    victims = {rids[1], rids[4]}
    cancel_at = {int(ctx.rng.integers(1, 5)): rids[1],
                 int(ctx.rng.integers(5, 10)): rids[4]}

    def chaos_cancel(value, engine=None, step=None, **kw):
        rid = cancel_at.pop(step, None)
        if rid is not None:
            engine.cancel(rid)
        return value

    with hooks.installed("serve.pre_step", chaos_cancel):
        res = eng.run()
    eng.check_invariants()
    ctx.check("engine drained under the cancel storm", not eng.busy)
    survivors = [r for r in rids if r not in victims]
    ctx.check("every non-cancelled request finished",
              all(res[r]["state"] == "done" for r in survivors),
              repr({r: res[r]["state"] for r in rids}))
    ctx.check("every non-cancelled request got all its tokens",
              all(len(res[r]["tokens"]) == 8 for r in survivors))
    ctx.check("all KV pages freed after drain",
              eng.allocator.available == free0,
              f"{eng.allocator.available}/{free0}")


def _src_path() -> str:
    """Repo `src/` dir (so subprocess children can import repro)."""
    return os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", ".."))


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def run_scenarios(selector: str = "fast", seed: int = 0,
                  journal: str | None = None, keep_work: bool = False,
                  echo: Callable[[str], None] = print
                  ) -> list[ScenarioResult]:
    """Run the selected scenarios under a seeded schedule.

    Each scenario gets a fresh scratch dir and a clean handler registry;
    results (and per-check details) go to `journal` as JSONL when given.
    """
    selected = names(selector)
    base = tempfile.mkdtemp(prefix="repro-chaos-")
    results: list[ScenarioResult] = []
    try:
        for name in selected:
            fn, _ = _REGISTRY[name]
            ctx = Ctx(name, seed, os.path.join(base, name))
            os.makedirs(ctx.workdir, exist_ok=True)
            hooks.clear()
            t0 = time.perf_counter()
            error = None
            try:
                fn(ctx)
            except hooks.SimulatedCrash:
                error = ("SimulatedCrash escaped the scenario "
                         "(missing expect_crash guard)")
            except Exception:  # noqa: BLE001 - reported per scenario
                error = traceback.format_exc(limit=8)
            finally:
                hooks.clear()
            dt = time.perf_counter() - t0
            ok = (error is None and bool(ctx.checks)
                  and all(c.ok for c in ctx.checks))
            results.append(ScenarioResult(name, seed, ok, dt,
                                          ctx.checks, error))
            n_ok = sum(c.ok for c in ctx.checks)
            echo(f"[chaos] {'PASS' if ok else 'FAIL'} {name:36s} "
                 f"{n_ok}/{len(ctx.checks)} checks  {dt:.2f}s")
            if not ok:
                for c in ctx.checks:
                    if not c.ok:
                        echo(f"[chaos]   FAILED CHECK: {c.name}"
                             f"{'  -- ' + c.detail if c.detail else ''}")
                if error:
                    echo(f"[chaos]   ERROR: {error.strip().splitlines()[-1]}")
    finally:
        if not keep_work:
            shutil.rmtree(base, ignore_errors=True)
        else:
            echo(f"[chaos] scratch kept at {base}")
    if journal:
        os.makedirs(os.path.dirname(os.path.abspath(journal)), exist_ok=True)
        with open(journal, "w") as f:
            for r in results:
                f.write(json.dumps(r.to_record()) + "\n")
            f.write(json.dumps({
                "summary": True, "selector": selector, "seed": seed,
                "n_scenarios": len(results),
                "n_passed": sum(r.ok for r in results)}) + "\n")
    return results
