"""Chaos injection points (`repro.chaos`; DESIGN.md §15).

Production modules call ``chaos_point(name, value)`` at the seams the
fault-injection harness needs -- immediately before a checkpoint rename,
inside the prefetch producer loop, on the sentinel's input record, and so
on.  With no handler installed the call is a module-level bool check and
returns ``value`` unchanged, so the seams are zero-cost in real runs.

Two handler shapes share one registry:

  * crash/stall handlers ignore ``value`` and raise (``SimulatedCrash``)
    or sleep -- used for kill-mid-write and queue-stall scenarios;
  * transform handlers return a replacement ``value`` -- used to poison
    the host-side loss or the sentinel's health record.

``SimulatedCrash`` derives from ``BaseException`` on purpose: a SIGKILL
does not unwind through ``except Exception`` recovery paths, and neither
may its in-process stand-in (the trainer's retry loop must not "recover"
from a simulated process death).

Real process death for subprocess scenarios comes from the environment:
``REPRO_CHAOS_KILL=<point>[:<nth>]`` arms an ``os._exit(137)`` on the
nth hit of that point in this process (read once at import, so set it
before launching the child that should die).
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Any, Callable

KILL_ENV = "REPRO_CHAOS_KILL"
KILL_EXIT_CODE = 137          # what a SIGKILL-ed shell child reports


class SimulatedCrash(BaseException):
    """In-process stand-in for SIGKILL at a chaos point."""


_lock = threading.Lock()
_handlers: dict[str, list[Callable[..., Any]]] = {}
_armed = False                # fast-path gate: True iff any handler exists
_env_installed = False


def _rearm() -> None:
    global _armed
    _armed = any(_handlers.values())


def install(point: str, handler: Callable[..., Any]) -> Callable[..., Any]:
    """Register `handler(value, **ctx) -> value` at `point`; returns it."""
    with _lock:
        _handlers.setdefault(point, []).append(handler)
        _rearm()
    return handler


def uninstall(point: str, handler: Callable[..., Any]) -> None:
    """Remove one previously installed handler (no-op if absent)."""
    with _lock:
        lst = _handlers.get(point, [])
        if handler in lst:
            lst.remove(handler)
        _rearm()


def clear() -> None:
    """Drop every handler (scenario teardown)."""
    with _lock:
        _handlers.clear()
        _rearm()


@contextlib.contextmanager
def installed(point: str, handler: Callable[..., Any]):
    """Scoped `install`; always uninstalls, even on SimulatedCrash."""
    install(point, handler)
    try:
        yield handler
    finally:
        uninstall(point, handler)


def chaos_point(point: str, value: Any = None, **ctx: Any) -> Any:
    """Run any handlers installed at `point`; identity when disarmed.

    Handlers run in installation order; each receives the previous
    handler's return as `value` plus the call-site keyword context.
    """
    if not _armed:
        return value
    with _lock:
        handlers = list(_handlers.get(point, ()))
    for h in handlers:
        value = h(value, **ctx)
    return value


def crash_handler(nth: int = 1) -> Callable[..., Any]:
    """Handler raising SimulatedCrash on its nth invocation."""
    hits = {"n": 0}

    def handler(value, **ctx):
        hits["n"] += 1
        if hits["n"] >= nth:
            raise SimulatedCrash(f"chaos crash (hit {hits['n']})")
        return value
    return handler


def kill_env(point: str, nth: int = 1) -> dict[str, str]:
    """Env block arming a hard `os._exit` at `point` in a child process."""
    return {KILL_ENV: f"{point}:{nth}"}


def _install_env_kill() -> None:
    """Latch REPRO_CHAOS_KILL (read once, at import) into a kill handler."""
    global _env_installed
    spec = os.environ.get(KILL_ENV)
    if _env_installed or not spec:
        return
    _env_installed = True
    point, _, nth_s = spec.partition(":")
    nth = int(nth_s) if nth_s else 1
    hits = {"n": 0}

    def die(value, **ctx):
        hits["n"] += 1
        if hits["n"] >= nth:
            # die like SIGKILL: no atexit, no finally blocks, no flushes
            os._exit(KILL_EXIT_CODE)
        return value

    install(point, die)


_install_env_kill()
