"""Child process body for subprocess kill scenarios.

Runs a tiny recording train session over a packed-shard corpus and, if
it survives to the end, dumps one CRC per consumed batch.  The parent
(`scenarios.subprocess_kill_resume`) launches it three times: once as an
uninterrupted reference, once with ``REPRO_CHAOS_KILL`` armed (the env
hook in `hooks` hard-exits with ``os._exit(137)`` at the chaos point --
a faithful SIGKILL stand-in: no atexit, no finally, no flushes), and
once more to resume.  Token-stream CRCs are compared across the runs.
"""
from __future__ import annotations

import argparse
import json
import zlib


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--corpus", required=True)
    p.add_argument("--ckpt", required=True)
    p.add_argument("--total", type=int, required=True)
    p.add_argument("--out", required=True)
    a = p.parse_args()

    import numpy as np

    from repro.data.shards import ShardReader
    from repro.data.stream import PackedStream
    from repro.train.trainer import Trainer, TrainerConfig

    recs: list[dict] = []

    def step_fn(state, batch):
        s = int(state["step"])
        tok = np.asarray(batch["tokens"])
        recs.append({"step": s, "crc": zlib.crc32(tok.tobytes())})
        return {"step": np.int32(s + 1)}, {"loss": np.float32(1.0)}

    loader = PackedStream(ShardReader(a.corpus), seq_len=32, batch_size=2,
                          seed=0)
    cfg = TrainerConfig(total_steps=a.total, ckpt_dir=a.ckpt, ckpt_every=4,
                        log_every=10_000)
    Trainer(step_fn, {"step": np.int32(0)}, loader=loader, cfg=cfg).run()
    with open(a.out, "w") as f:
        json.dump(recs, f)


if __name__ == "__main__":
    main()
