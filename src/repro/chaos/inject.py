"""Fault injectors for the chaos harness (DESIGN.md §15).

Each injector is either a handler for a `hooks.chaos_point` seam or a
direct filesystem mutation.  They are deliberately small and composable:
scenarios (`chaos/scenarios.py`) wire them to seeded schedules and assert
the recovery invariants; the injectors themselves carry no policy.

Seam vocabulary (the points production code exposes):

    ckpt.pre_arrays / ckpt.pre_manifest / ckpt.pre_rename /
    ckpt.post_rename          train/checkpoint.py `save`
    shard.pre_idx / shard.pre_manifest
                              data/shards.py `ShardWriter`
    prefetch.tick             data/prefetch.py producer loop (per draw)
    trainer.loss              host-side loss scalar, after device_get
    sentinel.obs              the record CollapseSentinel.observe sees
    serve.pre_step            serve/engine.py `ServeEngine.step`
"""
from __future__ import annotations

import os
import time

import numpy as np

from . import hooks


# --------------------------------------------------------------------------
# value-poisoning handlers (transform seams)
# --------------------------------------------------------------------------

def nan_loss_burst(steps):
    """`trainer.loss` handler: loss becomes NaN on the given step numbers.

    Models an FP4 divergence burst (paper Fig. 6c) without touching the
    jitted step -- the trainer's NaN-skip budget is the path under test.
    """
    steps = frozenset(int(s) for s in steps)

    def handler(loss, step=None, **ctx):
        return float("nan") if step in steps else loss
    return handler


def outlier_obs_burst(steps, *, snr_db: float = -3.0,
                      clamp_frac: float = 0.9):
    """`sentinel.obs` handler: health record shows a collapse signature.

    Overwrites the aggregate keys the sentinel thresholds (SNR through
    the floor, clamp fraction far above the OCC quantile design) on the
    scheduled steps -- the trip -> checkpoint -> bf16-fallback path is
    the thing under test, not the metric computation.
    """
    steps = frozenset(int(s) for s in steps)

    def handler(obs, step=None, **ctx):
        if step in steps and obs is not None:
            obs = dict(obs, **{"agg/min_snr_db": snr_db,
                               "agg/max_clamp_frac": clamp_frac})
        return obs
    return handler


def fail_step_once(step: int, exc: Exception | None = None):
    """Trainer `fail_injector`: simulated device loss at one step.

    Raises a plain Exception (unlike SimulatedCrash) because device loss
    *is* recoverable in-process: the trainer's retry path must roll back
    to the last checkpoint and continue.
    """
    armed = {"on": True}

    def injector(s):
        if s == step and armed["on"]:
            armed["on"] = False
            raise exc or RuntimeError(f"injected device loss at step {s}")
    return injector


# --------------------------------------------------------------------------
# crash / stall handlers (fire seams)
# --------------------------------------------------------------------------

def crash_at(point: str, nth: int = 1):
    """Install an in-process SIGKILL stand-in at `point` (returns handler).

    Pair with `hooks.uninstall` / `hooks.clear`, or use
    `hooks.installed(point, hooks.crash_handler(nth))` for scoping.
    """
    return hooks.install(point, hooks.crash_handler(nth))


def stall(gate, timeout: float = 30.0):
    """Handler that blocks on `gate` (a threading.Event) when not set.

    Installed on `prefetch.tick` it freezes the producer thread exactly
    where a slow filesystem would -- mid-draw, holding no lock the
    consumer needs.  The `timeout` bounds test runtime if a scenario
    forgets to release the gate.
    """
    def handler(value, **ctx):
        gate.wait(timeout)
        return value
    return handler


def sleep_stall(seconds: float):
    """Handler adding a fixed delay (coarse queue-pressure injection)."""
    def handler(value, **ctx):
        time.sleep(seconds)
        return value
    return handler


# --------------------------------------------------------------------------
# byte-level artifact corruption
# --------------------------------------------------------------------------

def corrupt_bytes(path: str, rng: np.random.Generator,
                  n_bytes: int = 64) -> None:
    """Overwrite `n_bytes` at random offsets with random bytes, in place."""
    size = os.path.getsize(path)
    if size == 0:
        return
    n = min(n_bytes, size)
    offsets = rng.integers(0, size, size=n)
    junk = rng.integers(0, 256, size=n, dtype=np.uint8)
    with open(path, "r+b") as f:
        for off, b in zip(offsets, junk):
            f.seek(int(off))
            f.write(bytes([int(b) ^ 0xFF]))


def truncate_file(path: str, keep_frac: float = 0.5) -> None:
    """Cut a file short -- the on-disk shape of a kill mid-write."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(int(size * keep_frac))


def garbage_file(path: str, payload: bytes = b"{]] not json") -> None:
    """Replace a file's contents wholesale (foreign/hostile artifact)."""
    with open(path, "wb") as f:
        f.write(payload)
