"""repro.chaos -- fault-injection & crash-consistency harness (DESIGN.md §15).

Three layers:

  * `hooks` -- zero-cost-when-disabled injection seams (`chaos_point`)
    that production code exposes at its crash-critical moments, plus the
    `REPRO_CHAOS_KILL` env protocol for real subprocess kills;
  * `inject` -- the fault menu: NaN/outlier bursts, simulated device
    loss, SIGKILL stand-ins, byte-level artifact corruption, queue
    stalls;
  * `scenarios` -- the seeded scenario runner that composes injectors,
    drives short train/data/serve sessions through them, and asserts the
    recovery invariants (`python -m repro.chaos --scenarios fast`).

Only `hooks` is imported here: production modules (trainer, checkpoint,
shards, prefetch, serve engine, sentinel) import `repro.chaos.hooks`,
and pulling the scenario runner in at that point would be a circular
import -- `scenarios` imports the whole stack it tests.
"""
from .hooks import (SimulatedCrash, chaos_point, clear, crash_handler,
                    install, installed, kill_env, uninstall)

__all__ = ["SimulatedCrash", "chaos_point", "clear", "crash_handler",
           "install", "installed", "kill_env", "uninstall"]
