"""CLI entry: `python -m repro.chaos --scenarios fast --seed 0`.

Runs the selected fault-injection scenarios (chaos/scenarios.py) under a
seeded schedule, prints one PASS/FAIL line per scenario, optionally
writes a JSONL journal (one record per scenario plus a trailing summary
line -- the artifact the CI chaos job uploads), and exits non-zero if
any scenario failed.
"""
from __future__ import annotations

import argparse
import sys

from . import scenarios


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.chaos",
        description="Fault-injection & crash-consistency scenario runner")
    p.add_argument("--scenarios", default="fast",
                   help="tag or comma list of tags/names "
                        "(fast, full, ckpt, data, trainer, serve, ...)")
    p.add_argument("--seed", type=int, default=0,
                   help="schedule seed (scenarios replay exactly per seed)")
    p.add_argument("--journal", default=None,
                   help="write a JSONL journal of results to this path")
    p.add_argument("--keep-work", action="store_true",
                   help="keep per-scenario scratch dirs for post-mortem")
    p.add_argument("--list", action="store_true",
                   help="list matching scenarios and exit")
    a = p.parse_args(argv)

    try:
        selected = scenarios.names(a.scenarios)
    except ValueError as e:
        p.error(str(e))
    if a.list:
        for name in selected:
            _, tags = scenarios._REGISTRY[name]
            doc = (scenarios._REGISTRY[name][0].__doc__ or "").split("\n")[0]
            print(f"{name:36s} [{','.join(sorted(tags - {'all'}))}]  {doc}")
        return 0

    results = scenarios.run_scenarios(a.scenarios, seed=a.seed,
                                      journal=a.journal,
                                      keep_work=a.keep_work)
    n_ok = sum(r.ok for r in results)
    print(f"[chaos] {n_ok}/{len(results)} scenarios green "
          f"(selector={a.scenarios!r} seed={a.seed})")
    return 0 if n_ok == len(results) and results else 1


if __name__ == "__main__":
    sys.exit(main())
