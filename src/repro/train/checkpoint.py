"""Checkpointing: atomic, durable, mesh-independent, resume-exact.

Layout (one directory per step):
    <root>/step_000123.tmp/...   (write)
    <root>/step_000123/          (atomic rename on completion)
        manifest.json            step, config hash, tree structure, dtypes
        arrays.npz               one entry per flattened leaf (host full
                                 arrays -- leaves are gathered; fp8 leaves
                                 stored as uint8 views + dtype tag)

Mesh independence: leaves are saved as *full* logical arrays, so restoring
onto any mesh shape is a plain device_put with the new sharding
(train/elastic.py). For 1000+-node scale the same layout shards the npz per
host; the manifest already records per-leaf byte ranges to support that.

Crash consistency (DESIGN.md §15): files are fsynced before the commit
rename and the parent directory after it, so a kill at any point leaves
either the old or the new checkpoint fully on disk.  Re-saving an
existing step (sentinel-trip rollback, resumed runs) never deletes the
target before the replacement is ready: the old directory is parked at
``step_N.old.<pid>`` for the duration of the swap, and ``clean_debris``
(run by every save/restore) renames it back if a crash struck between
the two renames.  Corrupt checkpoints raise ``CheckpointError``;
``restore(step=None)`` falls back to the newest *restorable* step
instead of crashing on -- or silently reusing -- damaged artifacts.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.chaos.hooks import chaos_point
from repro.dist import compat

_FP8_DTYPES = {"float8_e4m3fn": jnp.float8_e4m3fn,
               "float8_e5m2": jnp.float8_e5m2}

_STEP_RE = re.compile(r"step_(\d+)")
_OLD_RE = re.compile(r"(step_\d+)\.old\.\d+")


class CheckpointError(ValueError):
    """A checkpoint on disk is damaged (truncated, corrupted, unreadable)."""


def _fsync_path(path: str) -> None:
    """fsync a file or directory so a kill after return cannot lose it."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def clean_debris(root: str) -> None:
    """Remove half-written save attempts; finish interrupted re-saves.

    ``step_N.tmp`` dirs are incomplete writes -- deleted.  A
    ``step_N.old.<pid>`` dir whose ``step_N`` is missing means the save
    died between parking the old checkpoint and committing the new one:
    the parked copy is renamed back (it is complete by construction).
    """
    if not os.path.isdir(root):
        return
    for d in os.listdir(root):
        p = os.path.join(root, d)
        m = _OLD_RE.fullmatch(d)
        if m:
            final = os.path.join(root, m.group(1))
            if os.path.exists(final):
                shutil.rmtree(p, ignore_errors=True)
            else:
                os.rename(p, final)
        elif d.endswith(".tmp") and _STEP_RE.fullmatch(d[:-4]):
            shutil.rmtree(p, ignore_errors=True)


def _flatten_with_paths(tree):
    flat, treedef = compat.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def tree_hash(tree) -> str:
    paths, leaves, _ = _flatten_with_paths(
        jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), tree))
    blob = json.dumps([paths, [str(l) for l in leaves]]).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def save(root: str, step: int, state, extra: dict | None = None) -> str:
    """Atomic, durable checkpoint write. Returns final directory path.

    Safe against a kill at any point, including while replacing an
    existing ``step_N`` (see module docstring for the commit protocol).
    """
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(root, exist_ok=True)
    clean_debris(root)
    os.makedirs(tmp, exist_ok=True)
    chaos_point("ckpt.pre_arrays", path=tmp, step=step)

    paths, leaves, _ = _flatten_with_paths(state)
    arrays, dtypes = {}, {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:05d}"
        if arr.dtype.name in _FP8_DTYPES or arr.dtype.name == "bfloat16":
            # npz has no ml_dtypes support: store raw bits + dtype tag
            dtypes[key] = arr.dtype.name
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        arrays[key] = arr
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **arrays)
    _fsync_path(arrays_path)
    chaos_point("ckpt.pre_manifest", path=tmp, step=step)
    manifest = {
        "step": step,
        "paths": paths,
        "special_dtypes": dtypes,
        "tree_hash": tree_hash(state),
        "extra": extra or {},
    }
    manifest_path = os.path.join(tmp, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)
    chaos_point("ckpt.pre_rename", path=tmp, step=step)
    # Commit: never a window with step_N absent *and* unrecoverable --
    # the old dir is parked (atomic rename), the tmp promoted (atomic
    # rename), and clean_debris un-parks the old one after a crash
    # between the two.
    old = None
    if os.path.exists(final):
        old = f"{final}.old.{os.getpid()}"
        os.rename(final, old)
    os.rename(tmp, final)
    _fsync_path(root)             # make both renames durable
    chaos_point("ckpt.post_rename", path=final, step=step)
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    clean_debris(root)       # an interrupted re-save must still count
    steps = [int(m.group(1)) for d in os.listdir(root)
             if (m := _STEP_RE.fullmatch(d))]
    return max(steps) if steps else None


def _restore_dir(d: str, state_template, shardings):
    """Load one checkpoint directory; CheckpointError on damage."""
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if not isinstance(manifest, dict) or "paths" not in manifest:
            raise CheckpointError(f"manifest under {d} is not a checkpoint "
                                  "manifest")
    except CheckpointError:
        raise
    except (OSError, ValueError) as e:
        raise CheckpointError(f"corrupt checkpoint manifest under {d}: "
                              f"{e}") from e

    tmpl_paths, tmpl_leaves, treedef = _flatten_with_paths(state_template)
    if manifest["paths"] != tmpl_paths:
        raise ValueError("checkpoint tree structure mismatch "
                         f"({len(manifest['paths'])} vs {len(tmpl_paths)} leaves)")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(tmpl_leaves))

    import ml_dtypes
    _BITS = {"float8_e4m3fn": ml_dtypes.float8_e4m3fn,
             "float8_e5m2": ml_dtypes.float8_e5m2,
             "bfloat16": ml_dtypes.bfloat16}
    # Materialize every leaf on the host inside the guard: a flipped bit
    # in the npz surfaces as BadZipFile/zlib error/KeyError at member
    # access time, not at np.load.
    try:
        data = np.load(os.path.join(d, "arrays.npz"))
        host = []
        for i in range(len(tmpl_leaves)):
            arr = data[f"leaf_{i:05d}"]
            special = manifest["special_dtypes"].get(f"leaf_{i:05d}")
            if special:
                arr = arr.view(_BITS[special])
            host.append(arr)
    except Exception as e:  # noqa: BLE001 -- zip/zlib/npy-format/OS damage
        raise CheckpointError(f"corrupt checkpoint arrays under {d}: "
                              f"{e}") from e
    out = [jax.device_put(a, sh) if sh is not None else jnp.asarray(a)
           for a, sh in zip(host, shard_leaves)]
    return treedef.unflatten(out), manifest


def restore(root: str, state_template, step: int | None = None,
            shardings=None):
    """Restore into the structure of `state_template`. With `shardings`,
    leaves are device_put with the given sharding (elastic resharding).

    With an explicit `step`, damage raises `CheckpointError`.  With
    `step=None` the newest *restorable* checkpoint wins: corrupt ones
    are skipped with a warning, and only if every candidate is damaged
    does the call raise -- never a silent fresh start, never a crash on
    a single bad artifact.
    """
    if step is not None:
        return _restore_dir(os.path.join(root, f"step_{step:08d}"),
                            state_template, shardings)
    if not os.path.isdir(root):
        raise FileNotFoundError(f"no checkpoints under {root}")
    clean_debris(root)
    steps = sorted((int(m.group(1)) for d in os.listdir(root)
                    if (m := _STEP_RE.fullmatch(d))), reverse=True)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {root}")
    for s in steps:
        d = os.path.join(root, f"step_{s:08d}")
        try:
            return _restore_dir(d, state_template, shardings)
        except CheckpointError as e:
            warnings.warn(f"skipping corrupt checkpoint {d}: {e}",
                          stacklevel=2)
    raise CheckpointError(f"no restorable checkpoint under {root} "
                          f"({len(steps)} candidates, all corrupt)")


def keep_last(root: str, n: int = 3) -> None:
    """Retention policy: delete all but the newest n checkpoints."""
    if not os.path.isdir(root):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(root)
                   if (m := _STEP_RE.fullmatch(d)))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
