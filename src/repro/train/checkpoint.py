"""Checkpointing: atomic, mesh-independent, resume-exact.

Layout (one directory per step):
    <root>/step_000123.tmp/...   (write)
    <root>/step_000123/          (atomic rename on completion)
        manifest.json            step, config hash, tree structure, dtypes
        arrays.npz               one entry per flattened leaf (host full
                                 arrays -- leaves are gathered; fp8 leaves
                                 stored as uint8 views + dtype tag)

Mesh independence: leaves are saved as *full* logical arrays, so restoring
onto any mesh shape is a plain device_put with the new sharding
(train/elastic.py). For 1000+-node scale the same layout shards the npz per
host; the manifest already records per-leaf byte ranges to support that.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compat

_FP8_DTYPES = {"float8_e4m3fn": jnp.float8_e4m3fn,
               "float8_e5m2": jnp.float8_e5m2}


def _flatten_with_paths(tree):
    flat, treedef = compat.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def tree_hash(tree) -> str:
    paths, leaves, _ = _flatten_with_paths(
        jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), tree))
    blob = json.dumps([paths, [str(l) for l in leaves]]).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def save(root: str, step: int, state, extra: dict | None = None) -> str:
    """Atomic checkpoint write. Returns final directory path."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(state)
    arrays, dtypes = {}, {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:05d}"
        if arr.dtype.name in _FP8_DTYPES or arr.dtype.name == "bfloat16":
            # npz has no ml_dtypes support: store raw bits + dtype tag
            dtypes[key] = arr.dtype.name
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        arrays[key] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "special_dtypes": dtypes,
        "tree_hash": tree_hash(state),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(m.group(1)) for d in os.listdir(root)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(root: str, state_template, step: int | None = None,
            shardings=None):
    """Restore into the structure of `state_template`. With `shardings`,
    leaves are device_put with the given sharding (elastic resharding)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    tmpl_paths, tmpl_leaves, treedef = _flatten_with_paths(state_template)
    if manifest["paths"] != tmpl_paths:
        raise ValueError("checkpoint tree structure mismatch "
                         f"({len(manifest['paths'])} vs {len(tmpl_paths)} leaves)")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(tmpl_leaves))

    import ml_dtypes
    _BITS = {"float8_e4m3fn": ml_dtypes.float8_e4m3fn,
             "float8_e5m2": ml_dtypes.float8_e5m2,
             "bfloat16": ml_dtypes.bfloat16}
    out = []
    for i, (tmpl, sh) in enumerate(zip(tmpl_leaves, shard_leaves)):
        arr = data[f"leaf_{i:05d}"]
        special = manifest["special_dtypes"].get(f"leaf_{i:05d}")
        if special:
            arr = arr.view(_BITS[special])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), manifest


def keep_last(root: str, n: int = 3) -> None:
    """Retention policy: delete all but the newest n checkpoints."""
    if not os.path.isdir(root):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(root)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
