"""Train-step factories.

`make_train_step`  -- pjit/GSPMD end-to-end (params+optimizer sharded per
                      dist/sharding rules, ZeRO-1 optimizer states, per-layer
                      remat inside the model, chunked loss). This is what the
                      dry-run lowers.
`make_hier_train_step` -- multi-pod variant: shard_map *manual* over 'pod',
                      GSPMD auto inside; per-pod grads are synced across the
                      DCI hop in fp8 (dist/grad_comm.py), then the optimizer
                      runs on pod-identical grads.

Both return (step_fn, state_shardings, batch_sharding); state/batch must be
placed accordingly by the caller (trainer or dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat, grad_comm, sharding as shard_rules
from repro.optim import adam as adam_mod
from repro.optim.schedule import warmup_cosine


def init_state(model, adam_cfg: adam_mod.AdamConfig, key):
    """Returns (state pytree, logical-axes tree). Run under jax.eval_shape
    for the dry-run (no allocation)."""
    params, axes = model.init(key)
    opt = adam_mod.init_state(params, adam_cfg)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}, axes


def state_shardings(state, axes, mesh):
    """NamedShardings for the full train state (params + ZeRO-1 opt)."""
    p_shard = shard_rules.param_shardings(axes, state["params"], mesh)
    p_specs = jax.tree.map(lambda s: s.spec, p_shard)
    opt_per = adam_mod.zero1_specs(p_specs, state["params"], mesh)
    return {
        "params": p_shard,
        "opt": {"t": NamedSharding(mesh, P()), "per_param": opt_per},
        "step": NamedSharding(mesh, P()),
    }


def _loss_grads(model, params, batch, microbatch: int = 1):
    """Loss, metrics, and *unclipped* gradients, with optional microbatch
    accumulation (activation peak divides by `microbatch`; grads/optimizer
    memory unchanged).

    Clipping is the caller's job, applied only after ALL gradient
    accumulation (microbatches here, cross-pod sync in the hier step) so
    both the clip decision and the reported `grad_norm` see the true norm
    of the accumulated gradient -- never a mean of per-shard norms.
    """
    if microbatch <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
    else:
        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0, (b, microbatch)
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])
        mbs = jax.tree.map(split, batch)
        # Unrolled accumulation (microbatch is small): keeps the dry-run's
        # cost_analysis exact -- a lax.scan body would be counted once.
        # bf16 accumulator: the paper's recipe keeps *gradients* in fp8
        # (FP8-LM); bf16 here is the conservative middle ground and halves
        # the accumulator footprint vs f32.
        # Metrics accumulate generically (mean over microbatches) so extra
        # keys -- e.g. the quant-health tree under metrics["obs"] when
        # policy.obs_metrics is on -- ride along without a fixed template.
        loss = jnp.float32(0)
        metrics = None
        grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                             params)
        for i in range(microbatch):
            mb = jax.tree.map(lambda x: x[i], mbs)
            (l, m), g = jax.value_and_grad(
                lambda p: model.loss(p, mb), has_aux=True)(params)
            loss = loss + l / microbatch
            m_scaled = jax.tree.map(lambda v: v / microbatch, m)
            metrics = m_scaled if metrics is None else jax.tree.map(
                lambda a, v: a + v, metrics, m_scaled)
            grads = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.bfloat16) / microbatch,
                grads, g)
    return loss, dict(metrics, loss=loss), grads


def make_train_step(model, mesh, *, adam_cfg=None, total_steps: int = 10000,
                    peak_lr: float = 3e-4, clip_norm: float = 1.0,
                    donate: bool = True, microbatch: int = 1):
    adam_cfg = adam_cfg or adam_mod.AdamConfig()

    def train_step(state, batch):
        loss, metrics, grads = _loss_grads(model, state["params"], batch,
                                           microbatch)
        grads, gnorm = adam_mod.clip_by_global_norm(grads, clip_norm)
        metrics = dict(metrics, grad_norm=gnorm)
        lr = warmup_cosine(state["step"], total_steps=total_steps,
                           peak_lr=peak_lr)
        params, opt = adam_mod.apply_update(state["params"], grads,
                                            state["opt"], lr, adam_cfg)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_hier_train_step(model, mesh, *, adam_cfg=None,
                         total_steps: int = 10000, peak_lr: float = 3e-4,
                         clip_norm: float = 1.0, compress: bool = True):
    """Multi-pod: manual 'pod' axis, fp8 gradient sync across pods.

    Inside shard_map the batch is split over 'pod' (outer DP); params are
    replicated across pods. GSPMD still distributes over (data, model).
    """
    adam_cfg = adam_cfg or adam_mod.AdamConfig()
    assert "pod" in mesh.axis_names
    npod = mesh.shape["pod"]

    def _per_pod(state, batch, comm: bool):
        # `comm=False` is the collective-free twin used only under
        # jax.eval_shape to derive the output pytree (pmean/allreduce
        # and clipping preserve structure, shape, and dtype exactly, so
        # both arms emit identical templates) -- eval_shape cannot trace
        # collectives outside the shard_map axis context.
        loss, metrics, grads = _loss_grads(model, state["params"], batch)
        if comm:
            if compress:
                grads = grad_comm.fp8_allreduce_mean(grads, "pod")
            else:
                grads = grad_comm.bf16_allreduce_mean(grads, "pod")
        # clip AFTER the cross-pod sync: the clip decision and the
        # reported grad_norm are the true norm of the accumulated
        # (pod-mean) gradient, not a mean of per-pod norms.
        grads, gnorm = adam_mod.clip_by_global_norm(grads, clip_norm)
        if comm:
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"),
                                   metrics)
        metrics = dict(metrics, grad_norm=gnorm)
        lr = warmup_cosine(state["step"], total_steps=total_steps,
                           peak_lr=peak_lr)
        params, opt = adam_mod.apply_update(state["params"], grads,
                                            state["opt"], lr, adam_cfg)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, metrics

    per_pod = functools.partial(_per_pod, comm=True)
    template_cache: dict[Any, Any] = {}

    def _out_template(state, batch):
        """Abstract (new_state, metrics) pytree of one pod's step, via
        jax.eval_shape on the collective-free twin -- no fixed metrics
        dict, so models emitting extra keys (aux stats, metrics["obs"])
        shard_map cleanly."""
        flat, treedef = jax.tree.flatten((state, batch))
        key = (treedef, tuple((tuple(x.shape), str(x.dtype)) for x in flat))
        if key not in template_cache:
            def shrink(x):
                assert x.shape[0] % npod == 0, (x.shape, npod)
                return jax.ShapeDtypeStruct(
                    (x.shape[0] // npod,) + tuple(x.shape[1:]), x.dtype)
            template_cache[key] = jax.eval_shape(
                functools.partial(_per_pod, comm=False),
                state, jax.tree.map(shrink, batch))
        return template_cache[key]

    def train_step(state, batch):
        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        state_specs = jax.tree.map(lambda _: P(), state)
        out_specs = jax.tree.map(lambda _: P(), _out_template(state, batch))
        fn = compat.shard_map(per_pod, mesh=mesh,
                              in_specs=(state_specs, batch_specs),
                              out_specs=out_specs, axis_names={"pod"},
                              check_vma=False)
        return fn(state, batch)

    return train_step
