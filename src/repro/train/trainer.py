"""Training loop with production fault tolerance:

  * periodic atomic checkpoints + exact resume (data position = step)
  * NaN/Inf loss detection: skip the update, log, and abort after a budget
    (FP4 instability guard -- the paper's Fig. 6c divergence mode)
  * failure recovery: a step that raises is retried from the last good
    checkpoint (injectable failures for tests)
  * straggler watchdog: EWMA step-time anomaly detection with pluggable
    action (log / checkpoint-and-continue)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import checkpoint as ckpt_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    keep_ckpts: int = 3
    max_nan_skips: int = 5
    max_retries: int = 2
    log_every: int = 10
    straggler_ewma: float = 0.9
    straggler_k: float = 3.0     # flag step if > k x EWMA
    on_straggler: str = "log"    # "log" | "checkpoint"


class StragglerWatchdog:
    def __init__(self, cfg: TrainerConfig):
        self.cfg = cfg
        self.ewma = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.cfg.straggler_k * self.ewma
        if slow:
            self.flagged.append((step, dt))
        self.ewma = (self.cfg.straggler_ewma * self.ewma +
                     (1 - self.cfg.straggler_ewma) * dt)
        return slow


class Trainer:
    def __init__(self, step_fn: Callable, state, batch_fn: Callable,
                 cfg: TrainerConfig, place_batch: Callable | None = None,
                 fail_injector: Callable | None = None):
        """step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch
        (host numpy); place_batch optionally device_puts with shardings."""
        self.step_fn = step_fn
        self.state = state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.place_batch = place_batch or (lambda b: b)
        self.fail_injector = fail_injector
        self.watchdog = StragglerWatchdog(cfg)
        self.history: list[dict] = []
        self.nan_skips = 0
        self.start_step = int(jax.device_get(state["step"]))

    def _try_resume(self):
        if not self.cfg.ckpt_dir:
            return
        step = ckpt_mod.latest_step(self.cfg.ckpt_dir)
        if step is not None:
            self.state, manifest = ckpt_mod.restore(self.cfg.ckpt_dir,
                                                    self.state)
            self.start_step = int(jax.device_get(self.state["step"]))

    def _save(self, step: int):
        if self.cfg.ckpt_dir:
            ckpt_mod.save(self.cfg.ckpt_dir, step, self.state)
            ckpt_mod.keep_last(self.cfg.ckpt_dir, self.cfg.keep_ckpts)

    def run(self, resume: bool = True) -> list[dict]:
        if resume:
            self._try_resume()
        step = self.start_step
        retries = 0
        while step < self.cfg.total_steps:
            batch = self.place_batch(self.batch_fn(step))
            t0 = time.time()
            try:
                if self.fail_injector:
                    self.fail_injector(step)
                new_state, metrics = self.step_fn(self.state, batch)
                loss = float(jax.device_get(metrics["loss"]))
            except ckpt_mod.json.JSONDecodeError:  # pragma: no cover
                raise
            except Exception as e:  # noqa: BLE001 -- node-failure recovery
                retries += 1
                if retries > self.cfg.max_retries or not self.cfg.ckpt_dir:
                    raise
                self.state, _ = ckpt_mod.restore(self.cfg.ckpt_dir, self.state)
                step = int(jax.device_get(self.state["step"]))
                self.history.append({"step": step, "event": "restored",
                                     "error": repr(e)})
                continue
            dt = time.time() - t0
            if not np.isfinite(loss):
                # FP4 divergence guard: skip this update
                self.nan_skips += 1
                self.history.append({"step": step, "event": "nan_skip"})
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise FloatingPointError(
                        f"{self.nan_skips} non-finite losses; aborting")
                step += 1
                continue
            self.state = new_state
            slow = self.watchdog.observe(step, dt)
            if slow and self.cfg.on_straggler == "checkpoint":
                self._save(step)
            rec = {"step": step, "loss": loss, "dt": dt,
                   "grad_norm": float(jax.device_get(metrics["grad_norm"]))}
            self.history.append(rec)
            if step % self.cfg.ckpt_every == 0 and step > self.start_step:
                self._save(step)
            step += 1
        if self.cfg.ckpt_dir:
            self._save(step)
        return self.history
