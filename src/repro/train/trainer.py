"""Training loop with production fault tolerance:

  * periodic atomic checkpoints + exact resume (data position = step)
  * NaN/Inf loss detection: skip the update, log, and abort after a budget
    (FP4 instability guard -- the paper's Fig. 6c divergence mode)
  * failure recovery: a step that raises is retried from the last good
    checkpoint (injectable failures for tests)
  * straggler watchdog: EWMA step-time anomaly detection with pluggable
    action (log / checkpoint-and-continue)
  * quant-health observability (repro.obs; DESIGN.md §11): per-step JSONL
    sink + rolling window for the metrics["obs"] tree, and an activation-
    collapse sentinel that rides the NaN-skip machinery -- on trip the
    update is skipped, a checkpoint is written, and (when a fallback step
    function is provided) training flips to the bf16 arm.
  * streaming input (repro.data v2; DESIGN.md §14): instead of a
    step-indexed `batch_fn`, pass a checkpointable `loader` (PackedStream
    / SyntheticStream, optionally wrapped in a DevicePrefetcher). The
    loader's `state_dict()` is serialized into every checkpoint
    (`extra["data"]`) and restored on resume and on failure-recovery
    rollback, so the token stream is bit-exact across restarts.
    Input-pipeline health (data/stall_ms, data/queue_depth,
    data/pack_frac) rides the obs JSONL sink and rolling window.

Host transfers are batched: loss / grad_norm / obs are fetched with ONE
`jax.device_get` per step so device dispatch stays pipelined.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.chaos.hooks import chaos_point
from repro.obs import (CollapseSentinel, JsonlWriter, RollingWindow,
                       SentinelConfig)

from . import checkpoint as ckpt_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    keep_ckpts: int = 3
    max_nan_skips: int = 5
    max_retries: int = 2
    log_every: int = 10
    straggler_ewma: float = 0.9
    straggler_k: float = 3.0     # flag step if > k x EWMA
    on_straggler: str = "log"    # "log" | "checkpoint"
    # --- observability (metrics["obs"] from an obs_metrics policy) ---
    obs_jsonl: str | None = None      # per-step JSONL health log path
    obs_window: int = 128             # rolling-window length (percentiles)
    sentinel: SentinelConfig | None = None  # collapse sentinel (off = None)


class StragglerWatchdog:
    def __init__(self, cfg: TrainerConfig):
        self.cfg = cfg
        self.ewma = None
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.cfg.straggler_k * self.ewma
        if slow:
            self.flagged.append((step, dt))
        self.ewma = (self.cfg.straggler_ewma * self.ewma +
                     (1 - self.cfg.straggler_ewma) * dt)
        return slow


class Trainer:
    def __init__(self, step_fn: Callable, state, batch_fn: Callable = None,
                 cfg: TrainerConfig = None,
                 place_batch: Callable | None = None,
                 fail_injector: Callable | None = None,
                 fallback_step_fn: Callable | None = None,
                 loader=None):
        """step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch
        (host numpy); place_batch optionally device_puts with shardings.
        fallback_step_fn: bf16-policy step the collapse sentinel swaps to
        (built by the caller from model with policy.fallback()).
        loader: checkpointable stream (repro.data: next_batch/state_dict/
        load_state_dict) used instead of batch_fn; a DevicePrefetcher
        loader places its own batches, otherwise place_batch applies."""
        if (batch_fn is None) == (loader is None):
            raise ValueError("provide exactly one of batch_fn / loader")
        self.step_fn = step_fn
        self.state = state
        self.batch_fn = batch_fn
        self.loader = loader
        self.cfg = cfg
        self.place_batch = place_batch or (lambda b: b)
        self.fail_injector = fail_injector
        self.fallback_step_fn = fallback_step_fn
        self.watchdog = StragglerWatchdog(cfg)
        self.history: list[dict] = []
        self.nan_skips = 0
        self.start_step = int(jax.device_get(state["step"]))
        # observability sinks + sentinel
        self.obs_writer = JsonlWriter(cfg.obs_jsonl) if cfg.obs_jsonl else None
        self.obs_window = RollingWindow(cfg.obs_window)
        self.sentinel = CollapseSentinel(cfg.sentinel) if cfg.sentinel else None
        self.fallback_active = False
        self._last_data_stats: dict | None = None

    def obs_summary(self) -> dict:
        """Percentile summary of the rolling quant-health window."""
        return self.obs_window.summary()

    def _restore_data_state(self, manifest: dict):
        """Reseek the loader to the data cursor stored in a checkpoint."""
        if self.loader is None:
            return
        blob = (manifest.get("extra") or {}).get("data")
        if blob is not None:
            self.loader.load_state_dict(blob)

    def _try_resume(self):
        if not self.cfg.ckpt_dir:
            return
        # latest_step runs clean_debris: half-written .tmp dirs from a
        # killed save vanish, an interrupted re-save is rolled forward
        step = ckpt_mod.latest_step(self.cfg.ckpt_dir)
        if step is not None:
            self.state, manifest = ckpt_mod.restore(self.cfg.ckpt_dir,
                                                    self.state)
            self.start_step = int(jax.device_get(self.state["step"]))
            self._restore_data_state(manifest)

    def _save(self, step: int):
        if self.cfg.ckpt_dir:
            extra = None
            if self.loader is not None:
                # cursor of the next *unconsumed* batch (a prefetching
                # loader reports its consumed-state, not its read-ahead)
                extra = {"data": self.loader.state_dict()}
            ckpt_mod.save(self.cfg.ckpt_dir, step, self.state, extra=extra)
            ckpt_mod.keep_last(self.cfg.ckpt_dir, self.cfg.keep_ckpts)

    def _next_batch(self):
        """One batch from the loader: (device-ready batch, data stats).

        Stall time (host blocked waiting for input) is measured here; a
        warm DevicePrefetcher returns in microseconds, the blocking
        stream pays the full pack+read cost on the critical path."""
        t0 = time.perf_counter()
        pb = self.loader.next_batch()
        stall_ms = (time.perf_counter() - t0) * 1e3
        from repro.data.prefetch import DevicePrefetcher
        if isinstance(self.loader, DevicePrefetcher):
            batch = pb.arrays          # already staged by the prefetcher
            stats = dict(self.loader.stats(), stall_ms=stall_ms)
        else:
            batch = self.place_batch(pb.arrays)
            stats = {"stall_ms": stall_ms, "queue_depth": 0.0,
                     "pack_frac": pb.meta.get("pack_frac", 1.0)}
        self._last_data_stats = stats
        return batch

    def _fetch_host(self, step: int, metrics: dict):
        """ONE device_get per step (two transfers would serialize dispatch):
        loss always; grad_norm only when this step is logged; the obs tree
        only when a sink or the sentinel consumes it."""
        fetch: dict[str, Any] = {"loss": metrics["loss"]}
        log_this = (step % self.cfg.log_every == 0)
        if log_this and "grad_norm" in metrics:
            fetch["grad_norm"] = metrics["grad_norm"]
        obs_tree = metrics.get("obs")
        if obs_tree is not None and (
                self.obs_writer or self.sentinel is not None):
            fetch["obs"] = obs_tree
        host = jax.device_get(fetch)
        loss = float(host["loss"])
        gnorm = float(host["grad_norm"]) if "grad_norm" in host else None
        obs_host = None
        if "obs" in host:
            obs_host = {k: float(v) for k, v in host["obs"].items()}
        return loss, gnorm, obs_host

    def _handle_collapse(self, step: int, decision) -> None:
        """Sentinel tripped: ride the NaN-skip machinery -- skip the
        update, checkpoint the last good state, flip to the bf16 fallback
        step function when one was provided."""
        self.nan_skips += 1
        self.history.append({"step": step, "event": "collapse_trip",
                             "reasons": decision.reasons})
        if self.obs_writer:
            self.obs_writer.write({"step": step, "event": "collapse_trip",
                                   "reasons": decision.reasons})
        self._save(step)
        if self.fallback_step_fn is not None and not self.fallback_active:
            self.step_fn = self.fallback_step_fn
            self.fallback_active = True
            self.history.append({"step": step, "event": "bf16_fallback"})
        if self.nan_skips > self.cfg.max_nan_skips:
            raise FloatingPointError(
                f"{self.nan_skips} skipped updates (nan/collapse); aborting")

    def run(self, resume: bool = True) -> list[dict]:
        if resume:
            self._try_resume()
        step = self.start_step
        retries = 0
        while step < self.cfg.total_steps:
            if self.loader is not None:
                batch = self._next_batch()
            else:
                batch = self.place_batch(self.batch_fn(step))
            t0 = time.time()
            try:
                if self.fail_injector:
                    self.fail_injector(step)
                new_state, metrics = self.step_fn(self.state, batch)
                loss, gnorm, obs_host = self._fetch_host(step, metrics)
            except ckpt_mod.json.JSONDecodeError:  # pragma: no cover
                raise
            except Exception as e:  # noqa: BLE001 -- node-failure recovery
                retries += 1
                if retries > self.cfg.max_retries or not self.cfg.ckpt_dir:
                    raise
                self.state, manifest = ckpt_mod.restore(self.cfg.ckpt_dir,
                                                        self.state)
                step = int(jax.device_get(self.state["step"]))
                self._restore_data_state(manifest)
                self.history.append({"step": step, "event": "restored",
                                     "error": repr(e)})
                continue
            dt = time.time() - t0
            # chaos seam: NaN/Inf burst injection on the host-side loss
            # (exercises the skip-budget path without touching the jit)
            loss = chaos_point("trainer.loss", loss, step=step)
            data_stats = None
            if self._last_data_stats is not None:
                data_stats = {f"data/{k}": float(v)
                              for k, v in self._last_data_stats.items()}
            if obs_host is not None or data_stats is not None:
                rec = {"step": step, "loss": loss}
                rec.update(obs_host or {})
                rec.update(data_stats or {})
                self.obs_window.push(rec)
                if self.obs_writer:
                    self.obs_writer.write(rec)
            if not np.isfinite(loss):
                # FP4 divergence guard: skip this update
                self.nan_skips += 1
                self.history.append({"step": step, "event": "nan_skip"})
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise FloatingPointError(
                        f"{self.nan_skips} non-finite losses; aborting")
                step += 1
                continue
            if self.sentinel is not None and obs_host is not None:
                decision = self.sentinel.observe(step, obs_host)
                if decision.tripped:
                    self._handle_collapse(step, decision)
                    step += 1
                    continue
            self.state = new_state
            slow = self.watchdog.observe(step, dt)
            if slow and self.cfg.on_straggler == "checkpoint":
                self._save(step)
            rec = {"step": step, "loss": loss, "dt": dt}
            if gnorm is not None:
                rec["grad_norm"] = gnorm
            self.history.append(rec)
            if step % self.cfg.ckpt_every == 0 and step > self.start_step:
                self._save(step)
            step += 1
        if self.cfg.ckpt_dir:
            self._save(step)
        if self.obs_writer:
            self.obs_writer.close()
        return self.history
