"""Elastic scaling: move a training state between mesh shapes.

Checkpoints are mesh-independent (full logical arrays, train/checkpoint.py),
so elastic restart is: load -> rebuild shardings for the new mesh ->
device_put. Batch-size/schedule invariance across DP width is the trainer's
job (global batch is fixed; per-shard batch = global/DP).

`reshard_state` also handles the live case (no checkpoint round-trip) for
in-job shrink/grow events: jax.device_put with the new NamedSharding
reshards across the new device set.
"""
from __future__ import annotations

import jax

from repro.dist import sharding as shard_rules
from repro.train import train_step as ts_mod


def reshard_state(state, axes, new_mesh):
    """Place an unsharded (or differently-sharded) state onto new_mesh."""
    shardings = ts_mod.state_shardings(state, axes, new_mesh)
    return jax.device_put(state, shardings)


def elastic_restore(ckpt_dir: str, state_template, axes, new_mesh,
                    step: int | None = None):
    """Checkpoint -> new mesh in one call."""
    from repro.train import checkpoint as ckpt_mod
    shardings = ts_mod.state_shardings(state_template, axes, new_mesh)
    return ckpt_mod.restore(ckpt_dir, state_template, step=step,
                            shardings=shardings)
